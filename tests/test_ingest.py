"""Batched ingest path: segmentation parity, WAN batch semantics, sort-based
reassembly (all backends), timeout/loss accounting, telemetry feedback, and
the closed-loop driver (DESIGN.md §Ingest)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.testing.hypo import given, settings, st

from repro.core import EpochManager, MemberSpec
from repro.core.dataplane import DataPlane
from repro.core.protocol import (
    decode_seg_headers,
    encode_seg_headers,
    split64,
)
from repro.data.daq import DAQConfig, DAQFleet, EventBundle
from repro.data.reassembly import (
    BatchReassembler,
    reassembly_plan,
    reassembly_plan_np,
)
from repro.data.segmentation import (
    PacketBatch,
    batch_from_segments,
    segment_bundle,
    segment_bundles,
)
from repro.data.transport import TransportConfig, WANTransport


def _bundle(nbytes, ev=7, daq=0, entropy=3):
    rng = np.random.default_rng(ev)
    return EventBundle(ev, daq, entropy,
                       rng.integers(0, 256, nbytes).astype(np.uint8))


def _window(n_triggers=10, n_daqs=3, seed=0, mean=25_000):
    fleet = DAQFleet(DAQConfig(n_daqs=n_daqs, mean_bundle_bytes=mean, seed=seed))
    return fleet.bundle_window(n_triggers)


class TestSegHeaders:
    def test_roundtrip_words(self):
        w = encode_seg_headers([3, 70000 & 0xFFFF], [0, 9], [4, 4], [100, 8192 & 0xFFFF])
        f = decode_seg_headers(w)
        assert f["daq_id"].tolist() == [3, 70000 & 0xFFFF]
        assert f["seg_index"].tolist() == [0, 9]
        assert f["n_segs"].tolist() == [4, 4]

    def test_batch_seg_words(self):
        batch = segment_bundles([_bundle(30_000)])
        f = decode_seg_headers(batch.seg_words())
        assert np.array_equal(f["seg_index"], batch.seg_index.astype(np.uint32))
        assert np.array_equal(f["payload_len"],
                              batch.payload_len.astype(np.uint32))


class TestBatchedSegmentation:
    @given(nbytes=st.integers(1, 120_000))
    @settings(max_examples=20)
    def test_parity_with_perpacket(self, nbytes):
        """segment_bundles == stacked segment_bundle, field for field."""
        bundles = [_bundle(nbytes, ev=11, daq=2, entropy=5), _bundle(777)]
        batch = segment_bundles(bundles)
        ref = batch_from_segments(
            [s for b in bundles for s in segment_bundle(b)])
        for f in ("headers", "daq_id", "seg_index", "n_segs", "payload_len",
                  "payload", "event_number", "entropy"):
            assert np.array_equal(getattr(batch, f), getattr(ref, f)), f

    def test_take_and_concat(self):
        batch = segment_bundles([_bundle(20_000), _bundle(9_000, ev=9)])
        idx = np.arange(len(batch))[::-1]
        rev = batch.take(idx)
        assert np.array_equal(rev.seg_index, batch.seg_index[::-1])
        cat = PacketBatch.concat([batch, rev])
        assert len(cat) == 2 * len(batch)

    def test_empty_window(self):
        assert len(segment_bundles([])) == 0


class TestWANBatch:
    @given(seed=st.integers(0, 200))
    @settings(max_examples=15)
    def test_duplicate_follows_original(self, seed):
        """The dup-ordering fix: a duplicate never precedes its first copy,
        in both the batched and the per-packet path."""
        batch = segment_bundles(_window(6, seed=seed))
        cfg = TransportConfig(reorder_window=64, duplicate_prob=0.3,
                              loss_prob=0.05, seed=seed)
        for deliver in ("batch", "list"):
            wan = WANTransport(cfg)
            if deliver == "batch":
                wan.deliver_batch(batch)
            else:
                wan.deliver([s for b in _window(6, seed=seed)
                             for s in segment_bundle(b)])
            src, is_dup = wan.last_delivery
            first = {}
            for pos, (s, d) in enumerate(zip(src, is_dup)):
                if not d:
                    first.setdefault(int(s), pos)
            for pos, (s, d) in enumerate(zip(src, is_dup)):
                if d:
                    assert first[int(s)] < pos

    def test_loss_accounting(self):
        batch = segment_bundles(_window(10))
        wan = WANTransport(TransportConfig(loss_prob=0.2, seed=1))
        out = wan.deliver_batch(batch)
        assert len(out) == len(batch) - wan.n_lost
        assert wan.n_lost > 0

    @given(seed=st.integers(0, 300))
    @settings(max_examples=15)
    def test_deliver_and_deliver_batch_agree(self, seed):
        """Both paths draw from the same per-window stream: identical seeds
        and window sequence => identical delivery order, ``n_lost``/``n_dup``
        counters and ``last_delivery`` bookkeeping. (Historically ``deliver``
        used an independent np.random stream and could silently diverge.)"""
        bundles = _window(5, seed=seed)
        batch = segment_bundles(bundles)
        segs = [s for b in bundles for s in segment_bundle(b)]
        assert len(segs) == len(batch)
        cfg = TransportConfig(reorder_window=32, loss_prob=0.1,
                              duplicate_prob=0.1, seed=seed)
        wan_b, wan_l = WANTransport(cfg), WANTransport(cfg)
        for _ in range(3):  # windows advance in lockstep on both paths
            out_b = wan_b.deliver_batch(batch)
            out_l = wan_l.deliver(segs)
            assert wan_b.n_lost == wan_l.n_lost
            assert wan_b.n_dup == wan_l.n_dup
            np.testing.assert_array_equal(wan_b.last_delivery[0],
                                          wan_l.last_delivery[0])
            np.testing.assert_array_equal(wan_b.last_delivery[1],
                                          wan_l.last_delivery[1])
            np.testing.assert_array_equal(
                out_b.event_number,
                np.asarray([s.event_number for s in out_l], np.uint64))
            np.testing.assert_array_equal(
                out_b.seg_index,
                np.asarray([s.seg_index for s in out_l], np.int32))

    def test_deterministic_per_window(self):
        batch = segment_bundles(_window(5))
        a = WANTransport(TransportConfig(reorder_window=32, seed=4))
        b = WANTransport(TransportConfig(reorder_window=32, seed=4))
        assert np.array_equal(a.deliver_batch(batch).seg_index,
                              b.deliver_batch(batch).seg_index)


class TestBatchReassembler:
    @given(seed=st.integers(0, 500))
    @settings(max_examples=15)
    def test_never_corrupt(self, seed):
        """Property: under loss+dup+reorder, across split windows, every
        completed bundle is byte-identical; losses surface as incomplete or
        timed-out groups — never corrupt output."""
        bundles = _window(8, seed=seed)
        by_key = {(b.event_number, b.daq_id): b.payload for b in bundles}
        wan = WANTransport(TransportConfig(
            reorder_window=64, loss_prob=0.1, duplicate_prob=0.1, seed=seed))
        out = wan.deliver_batch(segment_bundles(bundles))
        ra = BatchReassembler(timeout_windows=8)
        cut = len(out) // 3
        ra.push_batch(out.take(np.arange(cut)))
        ra.push_batch(out.take(np.arange(cut, len(out))))
        for key, payload in ra.completed:
            assert np.array_equal(payload, by_key[key])
        if wan.n_lost == 0:
            assert ra.stats.n_completed == len(by_key)

    def test_backend_parity(self):
        """np / jnp / pallas plans agree on completion, dedup and grouping."""
        import jax.numpy as jnp

        rng = np.random.default_rng(3)
        n = 257
        ev = rng.integers(0, 40, n).astype(np.uint64)
        hi, lo = split64(ev)
        daq = rng.integers(0, 4, n).astype(np.int32)
        seg = rng.integers(0, 5, n).astype(np.int32)
        nsg = rng.integers(1, 6, n).astype(np.int32)
        host = reassembly_plan_np(hi, lo, daq, seg, nsg)
        n_pad = 512
        pad = lambda x, d: jnp.asarray(np.concatenate(
            [x, np.zeros((n_pad - n,), d)]).astype(d))
        valid = np.zeros((n_pad,), bool)
        valid[:n] = True
        for backend in ("jnp", "pallas"):
            dev = reassembly_plan(
                pad(hi, np.uint32), pad(lo, np.uint32), pad(daq, np.int32),
                pad(seg, np.int32), pad(nsg, np.int32), jnp.asarray(valid),
                backend=backend, interpret=True)
            assert int(dev["n_groups"]) == host["n_groups"]
            dperm = np.asarray(dev["perm"])[:n]
            assert np.array_equal(dperm, host["perm"])
            for k in ("new_group", "dup", "unique", "complete"):
                assert np.array_equal(
                    np.asarray(dev[k])[:n].astype(bool),
                    np.asarray(host[k]).astype(bool)), (backend, k)

    def test_duplicates_absorbed(self):
        bundles = [_bundle(30_000)]
        batch = segment_bundles(bundles)
        twice = PacketBatch.concat([batch, batch.take(np.arange(3))])
        ra = BatchReassembler()
        done = ra.push_batch(twice)
        assert len(done) == 1 and np.array_equal(done[0], bundles[0].payload)
        assert ra.n_duplicate == 3

    def test_timeout_accounting(self):
        batch = segment_bundles([_bundle(40_000)])
        ra = BatchReassembler(timeout_windows=2)
        ra.push_batch(batch.take(np.arange(len(batch) - 1)))  # drop last seg
        assert ra.n_incomplete == 1
        empty = batch.take(np.asarray([], np.int64))
        expired_keys = []
        for _ in range(3):
            ra.push_batch(empty)
            expired_keys.extend(ra.last_timed_out_keys)
        assert ra.n_incomplete == 0
        assert ra.stats.n_timed_out_groups == 1
        assert ra.stats.n_timed_out_segments == len(batch) - 1
        assert expired_keys == [(7, 0)]  # the expired (event, daq) surfaced

    def test_timeout_is_group_activity_based(self):
        """A late segment resets its group's timer; when the group finally
        expires it leaves whole and is counted exactly once."""
        rng = np.random.default_rng(0)
        b = EventBundle(42, 0, 1, rng.integers(0, 256, 4 * 2048).astype(np.uint8))
        batch = segment_bundles([b], 2048)
        ra = BatchReassembler(2048, timeout_windows=2)
        empty = batch.take(np.asarray([], np.int64))
        ra.push_batch(batch.take(np.asarray([0, 1])))
        ra.push_batch(empty)
        ra.push_batch(batch.take(np.asarray([2])))  # activity: timer resets
        assert ra.n_incomplete == 1  # segs 0,1 not expired separately
        for _ in range(3):
            ra.push_batch(empty)
        assert ra.stats.n_timed_out_groups == 1
        assert ra.stats.n_timed_out_segments == 3
        assert ra.n_incomplete == 0

    def test_dataplane_facade(self):
        """segment/route/reassemble all through the DataPlane facade."""
        em = EpochManager(max_members=8)
        em.initialize({i: MemberSpec(node_id=i, lane_bits=1) for i in range(4)},
                      {i: 1.0 for i in range(4)})
        dp = DataPlane.from_manager(em, backend="jnp")
        bundles = _window(6)
        batch = dp.segment(bundles)
        import jax.numpy as jnp

        r = dp.route(jnp.asarray(batch.headers))
        member = np.asarray(r.member)
        assert np.asarray(r.valid).all()
        done = 0
        for m in np.unique(member):
            ra = dp.make_reassembler()
            done += len(ra.push_batch(batch.take(np.flatnonzero(member == m))))
        assert done == len(bundles)

    def test_device_plan_reassembler(self):
        em = EpochManager(max_members=8)
        em.initialize({0: MemberSpec(node_id=0)}, {0: 1.0})
        dp = DataPlane.from_manager(em, backend="jnp")
        ra = dp.make_reassembler(device_plan=True)
        assert ra.backend == "jnp"
        bundles = [_bundle(25_000)]
        done = ra.push_batch(segment_bundles(bundles))
        assert len(done) == 1
        assert np.array_equal(done[0], bundles[0].payload)


class TestTelemetryFeedback:
    def test_ingest_backlog_raises_fill(self):
        from repro.telemetry.metrics import TelemetryHub

        hub = TelemetryHub(queue_capacity=8)
        hub.report_step(0, step_time=0.1)
        hub.report_step(1, step_time=0.1)
        hub.report_ingest(0, pending=8, timed_out=2)
        hub.report_ingest(1, pending=0)
        snap = hub.snapshot()
        assert snap[0].fill > snap[1].fill
        assert hub.members[0].ingest_timed_out == 2

    def test_pipeline_surfaces_backlog(self):
        from repro.data.pipeline import StreamingPipeline

        em = EpochManager(max_members=16)
        em.initialize({i: MemberSpec(node_id=i, lane_bits=1) for i in range(4)},
                      {i: 1.0 for i in range(4)})
        p = StreamingPipeline(
            DAQConfig(n_daqs=3, mean_bundle_bytes=20_000, seed=2),
            TransportConfig(reorder_window=16, loss_prob=0.15, seed=2), em)
        p.pump(20)
        stats = p.reassembly_stats()
        backlog = p.ingest_backlog()
        assert stats.n_pushed > 0
        if p.wan.n_lost:
            assert sum(backlog.values()) > 0

    def test_control_plane_feedback_threshold(self):
        from repro.core.control_plane import (LoadBalancerControlPlane,
                                              MemberTelemetry)

        em = EpochManager(max_members=16)
        cp = LoadBalancerControlPlane(em)
        cp.start({i: MemberSpec(node_id=i) for i in range(3)})
        flat = {i: MemberTelemetry(fill=0.5, rate=1.0) for i in range(3)}
        assert cp.feedback(flat, current_event=100) is None  # nothing moved
        skew = {0: MemberTelemetry(fill=0.95), 1: MemberTelemetry(fill=0.1),
                2: MemberTelemetry(fill=0.1)}
        eid = cp.feedback(skew, current_event=200)
        assert eid is not None
        assert cp.weights[0] < cp.weights[1]

    def test_feedback_hysteresis_bounds_epochs(self):
        """Repeated skewed feedback without traffic progress reconfigures at
        most once — the calendar rows can't be exhausted by a hot PI loop."""
        from repro.core.control_plane import (LoadBalancerControlPlane,
                                              MemberTelemetry)

        em = EpochManager(max_members=16)
        cp = LoadBalancerControlPlane(em)
        cp.start({i: MemberSpec(node_id=i) for i in range(3)})
        skew = {0: MemberTelemetry(fill=0.95), 1: MemberTelemetry(fill=0.1),
                2: MemberTelemetry(fill=0.1)}
        ids = [cp.feedback(skew, current_event=100) for _ in range(10)]
        assert sum(x is not None for x in ids) == 1
        assert sum(1 for r in em.records.values() if r.active) <= 2


class TestClosedLoop:
    @pytest.mark.parametrize("scenario", ["loss", "elastic"])
    def test_driver_smoke(self, scenario):
        env = dict(os.environ)
        env["PYTHONPATH"] = ("src" + os.pathsep + env["PYTHONPATH"]
                             if env.get("PYTHONPATH") else "src")
        env.setdefault("JAX_PLATFORMS", "cpu")
        out = subprocess.run(
            [sys.executable, "scripts/run_closed_loop.py", "--steps", "12",
             "--scenario", scenario],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert out.returncode == 0, out.stdout + out.stderr
