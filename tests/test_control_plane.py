"""Control plane: telemetry-driven weighting, stragglers, failures, elastic."""
import numpy as np
import pytest

from repro.core import (ControlPolicy, EpochManager, LoadBalancerControlPlane,
                        MemberSpec, MemberTelemetry, route, split64)
from repro.core.calendar import calendar_counts
from repro.telemetry.metrics import TelemetryHub


def _cp(n=4):
    cp = LoadBalancerControlPlane(EpochManager(max_members=64),
                                  ControlPolicy(epoch_horizon=256))
    cp.start({i: MemberSpec(node_id=i) for i in range(n)})
    return cp


class TestWeighting:
    def test_straggler_sheds_slots(self):
        cp = _cp(4)
        ev = 0
        for _ in range(6):
            tele = {i: MemberTelemetry(fill=0.5) for i in range(4)}
            tele[2] = MemberTelemetry(fill=0.95)  # member 2 overloaded
            cp.update_weights(tele)
            ev += 300
            cp.schedule_epoch(ev)
        eid = cp.manager.current_epoch
        counts = calendar_counts(cp.manager.state.calendars[eid], 4)
        assert counts[2] < counts[0] * 0.6
        assert counts.sum() == 512  # never an empty slot

    def test_fast_member_gains(self):
        cp = _cp(3)
        for step in range(5):
            cp.update_weights({0: MemberTelemetry(fill=0.1),
                               1: MemberTelemetry(fill=0.5),
                               2: MemberTelemetry(fill=0.5)})
            cp.schedule_epoch((step + 1) * 300)
        eid = cp.manager.current_epoch
        counts = calendar_counts(cp.manager.state.calendars[eid], 3)
        assert counts[0] > counts[1]

    def test_weight_floor_keeps_member_reachable(self):
        cp = _cp(2)
        for step in range(20):
            cp.update_weights({0: MemberTelemetry(fill=1.0),
                               1: MemberTelemetry(fill=0.0)})
        assert cp.weights[0] >= cp.policy.min_weight


class TestFailureAndElastic:
    def test_failed_member_leaves_next_epoch(self):
        cp = _cp(4)
        cp.mark_failed([1])
        cp.schedule_epoch(current_event=100, boundary=500)
        em = cp.manager
        evs = np.arange(500, 1500, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(em.device_tables(), hi, lo, np.zeros(len(evs), np.uint32))
        assert 1 not in set(np.asarray(r.member).tolist())
        # in-flight events (< 500) still route to the old set incl. member 1
        hi0, lo0 = split64(np.arange(0, 500, dtype=np.uint64))
        r0 = route(em.device_tables(), hi0, lo0, np.zeros(500, np.uint32))
        assert 1 in set(np.asarray(r0.member).tolist())

    def test_elastic_add(self):
        cp = _cp(2)
        cp.add_members({5: MemberSpec(node_id=5), 6: MemberSpec(node_id=6)})
        cp.schedule_epoch(current_event=10, boundary=100)
        evs = np.arange(100, 612, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(cp.manager.device_tables(), hi, lo,
                  np.zeros(len(evs), np.uint32))
        assert {5, 6} <= set(np.asarray(r.member).tolist())

    def test_all_failed_raises(self):
        cp = _cp(2)
        cp.mark_failed([0, 1])
        with pytest.raises(RuntimeError):
            cp.schedule_epoch(100)


class TestGarbageCollect:
    def test_drained_epochs_freed(self):
        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)
        cp.schedule_epoch(current_event=600, boundary=1000)
        freed = cp.garbage_collect(processed_event=1000)
        assert freed  # both bounded epochs have drained
        assert cp.gc_skipped == []

    def test_epoch_state_error_is_recorded_not_swallowed(self, monkeypatch):
        from repro.core import ReconfigurationError

        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)

        def boom(eid):
            raise ReconfigurationError("still reachable")

        monkeypatch.setattr(cp.manager, "quiesce", boom)
        freed = cp.garbage_collect(processed_event=10_000)
        assert freed == []
        assert cp.gc_skipped and cp.gc_skipped[0][1] == "still reachable"

    def test_unexpected_errors_propagate(self, monkeypatch):
        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)
        monkeypatch.setattr(cp.manager, "quiesce",
                            lambda eid: (_ for _ in ()).throw(ValueError("bug")))
        with pytest.raises(ValueError):
            cp.garbage_collect(processed_event=10_000)


class TestTelemetryHub:
    def test_slow_member_reports_higher_fill(self):
        hub = TelemetryHub()
        for _ in range(10):
            hub.report_step(0, 0.1)
            hub.report_step(1, 0.4)  # 4x slower
        snap = hub.snapshot()
        assert snap[1].fill > snap[0].fill

    def test_failure_propagates(self):
        hub = TelemetryHub()
        hub.report_step(0, 0.1)
        hub.report_failure(0)
        assert not hub.snapshot()[0].healthy


class TestLeaseExpiryVsInflightEpochs:
    """Satellite for controld: a member whose lease lapses *between*
    schedule_epoch and the boundary must drain hit-lessly — the in-flight
    epoch is immutable (its events keep routing to the lapsed member, so
    their bundles are delivered and accounted), and the member leaves at
    the first post-boundary reconfiguration."""

    def test_drain_waits_for_the_inflight_boundary(self):
        cp = _cp(3)
        eid1 = cp.schedule_epoch(current_event=100, boundary=500)
        # the lease lapses now: controld calls exactly this on expiry
        cp.mark_failed([2])
        # hysteresis: while traffic is still before the scheduled boundary,
        # feedback must NOT reconfigure (the switch hasn't activated yet)
        tele = {0: MemberTelemetry(fill=0.5), 1: MemberTelemetry(fill=0.5)}
        assert cp.feedback(tele, current_event=300) is None
        assert cp.manager.current_epoch == eid1
        # in-flight events still route to the lapsed member — hit-less
        evs = np.arange(500, 1012, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(cp.manager.device_tables(), hi, lo,
                  np.zeros(len(evs), np.uint32))
        assert 2 in set(np.asarray(r.member).tolist())
        # once traffic crosses the boundary, the next feedback drains it
        eid2 = cp.feedback(tele, current_event=520)
        assert eid2 is not None
        b2 = cp.manager.records[eid2].start_event
        evs2 = np.arange(b2, b2 + 512, dtype=np.uint64)
        hi2, lo2 = split64(evs2)
        r2 = route(cp.manager.device_tables(), hi2, lo2,
                   np.zeros(512, np.uint32))
        assert 2 not in set(np.asarray(r2.member).tolist())

    def test_every_epochs_slots_stay_fully_programmed(self):
        """No half-programmed calendar anywhere in the transition: every
        resident epoch's 512 slots map to a valid member throughout."""
        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)
        cp.mark_failed([1])
        cp.feedback({0: MemberTelemetry(fill=0.5),
                     2: MemberTelemetry(fill=0.5)}, current_event=520)
        for eid, cal in cp.manager.state.calendars.items():
            counts = calendar_counts(cal, 3)
            assert counts.sum() == 512, f"epoch {eid} has unprogrammed slots"
            members = cp.manager.records[eid].members
            for m in set(np.unique(cal).tolist()):
                assert m in members


class TestPolicyDelegation:
    """update_weights now delegates to a pluggable WeightPolicy
    (repro.controld.policy); the default must be the historical PI update."""

    def test_default_reweighter_is_proportional_with_cp_gains(self):
        from repro.controld.policy import ProportionalPolicy

        cp = _cp(2)
        assert isinstance(cp.reweighter, ProportionalPolicy)
        assert cp.reweighter.cfg.kp == cp.policy.kp
        assert cp.reweighter.cfg.min_weight == cp.policy.min_weight

    def test_custom_reweighter_is_used(self):
        from repro.controld.policy import PIDFillPolicy, PolicyConfig

        cp = LoadBalancerControlPlane(
            EpochManager(max_members=64), ControlPolicy(epoch_horizon=256),
            reweighter=PIDFillPolicy(PolicyConfig(kd=0.2)))
        cp.start({i: MemberSpec(node_id=i) for i in range(3)})
        w = cp.update_weights({i: MemberTelemetry(fill=0.2 + 0.3 * i)
                               for i in range(3)})
        assert w[0] > w[2]  # emptier member gains share

    def test_membership_changes_reach_the_policy(self):
        cp = _cp(2)
        cp.update_weights({0: MemberTelemetry(fill=0.9),
                           1: MemberTelemetry(fill=0.1)})
        assert 0 in cp.reweighter._integral
        cp.remove_members([0])
        assert 0 not in cp.reweighter._integral
        cp.add_members({5: MemberSpec(node_id=5)})
        assert cp.reweighter._integral[5] == 0.0
