"""Control plane: telemetry-driven weighting, stragglers, failures, elastic."""
import numpy as np
import pytest

from repro.core import (ControlPolicy, EpochManager, LoadBalancerControlPlane,
                        MemberSpec, MemberTelemetry, route, split64)
from repro.core.calendar import calendar_counts
from repro.telemetry.metrics import TelemetryHub


def _cp(n=4):
    cp = LoadBalancerControlPlane(EpochManager(max_members=64),
                                  ControlPolicy(epoch_horizon=256))
    cp.start({i: MemberSpec(node_id=i) for i in range(n)})
    return cp


class TestWeighting:
    def test_straggler_sheds_slots(self):
        cp = _cp(4)
        ev = 0
        for _ in range(6):
            tele = {i: MemberTelemetry(fill=0.5) for i in range(4)}
            tele[2] = MemberTelemetry(fill=0.95)  # member 2 overloaded
            cp.update_weights(tele)
            ev += 300
            cp.schedule_epoch(ev)
        eid = cp.manager.current_epoch
        counts = calendar_counts(cp.manager.state.calendars[eid], 4)
        assert counts[2] < counts[0] * 0.6
        assert counts.sum() == 512  # never an empty slot

    def test_fast_member_gains(self):
        cp = _cp(3)
        for step in range(5):
            cp.update_weights({0: MemberTelemetry(fill=0.1),
                               1: MemberTelemetry(fill=0.5),
                               2: MemberTelemetry(fill=0.5)})
            cp.schedule_epoch((step + 1) * 300)
        eid = cp.manager.current_epoch
        counts = calendar_counts(cp.manager.state.calendars[eid], 3)
        assert counts[0] > counts[1]

    def test_weight_floor_keeps_member_reachable(self):
        cp = _cp(2)
        for step in range(20):
            cp.update_weights({0: MemberTelemetry(fill=1.0),
                               1: MemberTelemetry(fill=0.0)})
        assert cp.weights[0] >= cp.policy.min_weight


class TestFailureAndElastic:
    def test_failed_member_leaves_next_epoch(self):
        cp = _cp(4)
        cp.mark_failed([1])
        cp.schedule_epoch(current_event=100, boundary=500)
        em = cp.manager
        evs = np.arange(500, 1500, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(em.device_tables(), hi, lo, np.zeros(len(evs), np.uint32))
        assert 1 not in set(np.asarray(r.member).tolist())
        # in-flight events (< 500) still route to the old set incl. member 1
        hi0, lo0 = split64(np.arange(0, 500, dtype=np.uint64))
        r0 = route(em.device_tables(), hi0, lo0, np.zeros(500, np.uint32))
        assert 1 in set(np.asarray(r0.member).tolist())

    def test_elastic_add(self):
        cp = _cp(2)
        cp.add_members({5: MemberSpec(node_id=5), 6: MemberSpec(node_id=6)})
        cp.schedule_epoch(current_event=10, boundary=100)
        evs = np.arange(100, 612, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(cp.manager.device_tables(), hi, lo,
                  np.zeros(len(evs), np.uint32))
        assert {5, 6} <= set(np.asarray(r.member).tolist())

    def test_all_failed_raises(self):
        cp = _cp(2)
        cp.mark_failed([0, 1])
        with pytest.raises(RuntimeError):
            cp.schedule_epoch(100)


class TestGarbageCollect:
    def test_drained_epochs_freed(self):
        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)
        cp.schedule_epoch(current_event=600, boundary=1000)
        freed = cp.garbage_collect(processed_event=1000)
        assert freed  # both bounded epochs have drained
        assert cp.gc_skipped == []

    def test_epoch_state_error_is_recorded_not_swallowed(self, monkeypatch):
        from repro.core import ReconfigurationError

        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)

        def boom(eid):
            raise ReconfigurationError("still reachable")

        monkeypatch.setattr(cp.manager, "quiesce", boom)
        freed = cp.garbage_collect(processed_event=10_000)
        assert freed == []
        assert cp.gc_skipped and cp.gc_skipped[0][1] == "still reachable"

    def test_unexpected_errors_propagate(self, monkeypatch):
        cp = _cp(3)
        cp.schedule_epoch(current_event=100, boundary=500)
        monkeypatch.setattr(cp.manager, "quiesce",
                            lambda eid: (_ for _ in ()).throw(ValueError("bug")))
        with pytest.raises(ValueError):
            cp.garbage_collect(processed_event=10_000)


class TestTelemetryHub:
    def test_slow_member_reports_higher_fill(self):
        hub = TelemetryHub()
        for _ in range(10):
            hub.report_step(0, 0.1)
            hub.report_step(1, 0.4)  # 4x slower
        snap = hub.snapshot()
        assert snap[1].fill > snap[0].fill

    def test_failure_propagates(self):
        hub = TelemetryHub()
        hub.report_step(0, 0.1)
        hub.report_failure(0)
        assert not hub.snapshot()[0].healthy
