"""Trainer: loss goes down, checkpoint/restart bit-exact resume, failure
handling recalendars, 8-bit Adam + grad compression behave."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.calendar import calendar_counts
from repro.distributed import compression as GC
from repro.train import optimizer as OPT
from repro.train import train_step as TS
from repro.train.trainer import Trainer, TrainerConfig


def _trainer(tmp, model="stablelm_3b", **tkw):
    cfg = get_smoke_config(model)
    tcfg = TS.TrainConfig(
        adamw=OPT.AdamWConfig(lr=1e-2, warmup_steps=2, decay_steps=100, **tkw),
        remat=False, lb_ingest=False, q_chunk=8, k_chunk=8)
    tr = Trainer(cfg, tcfg, TrainerConfig(
        n_members=4, ckpt_dir=str(tmp), ckpt_every=5, recalendar_every=4))
    return tr


class TestTraining:
    def test_loss_decreases(self, tmp_path):
        tr = _trainer(tmp_path / "a")
        tr.init_or_restore(jax.random.PRNGKey(0))
        hist = tr.run(12, batch=4, seq=16)
        first = np.mean([h["loss"] for h in hist[:3]])
        last = np.mean([h["loss"] for h in hist[-3:]])
        assert last < first  # memorizes synthetic tokens

    def test_checkpoint_resume_exact(self, tmp_path):
        d = tmp_path / "b"
        tr1 = _trainer(d)
        tr1.init_or_restore(jax.random.PRNGKey(0))
        tr1.run(10, batch=4, seq=16)  # ckpt at step 5, 10
        params_ref = jax.tree.map(np.asarray, tr1.state["params"])
        # simulated crash: new trainer restores from latest ckpt
        tr2 = _trainer(d)
        step = tr2.init_or_restore(jax.random.PRNGKey(1))  # different rng!
        assert step == 10
        for a, b in zip(jax.tree.leaves(params_ref),
                        jax.tree.leaves(tr2.state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_failure_triggers_recalendar(self, tmp_path):
        tr = _trainer(tmp_path / "c")
        tr.init_or_restore(jax.random.PRNGKey(0))
        tr.run(6, batch=4, seq=16, failure_at={2: [3]})
        em = tr.manager
        cal = em.state.calendars[em.current_epoch]
        assert 3 not in set(np.unique(cal))
        assert calendar_counts(cal, 4).sum() == 512

    def test_straggler_mitigation_end_to_end(self, tmp_path):
        """Member 2 reports 3x step time -> its calendar share shrinks."""
        tr = _trainer(tmp_path / "d")
        tr.init_or_restore(jax.random.PRNGKey(0))
        import time

        orig_report = tr.hub.report_step
        def biased(member_id, dt, **kw):
            orig_report(member_id, dt * (3.0 if member_id == 2 else 1.0), **kw)
        tr.hub.report_step = biased
        tr.run(12, batch=4, seq=16)
        cal = tr.manager.state.calendars[tr.manager.current_epoch]
        counts = calendar_counts(cal, 4)
        assert counts[2] < counts[0]


class TestOptimizer:
    def _quad_losses(self, eight_bit):
        cfg = OPT.AdamWConfig(lr=0.1, weight_decay=0.0, eight_bit=eight_bit,
                              warmup_steps=1, decay_steps=1000)
        params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)),
                                   jnp.float32)}
        target = jnp.ones((8, 8))
        state = OPT.init(params, cfg)
        losses = []
        for _ in range(40):
            loss, g = jax.value_and_grad(
                lambda p: jnp.mean((p["w"] - target) ** 2))(params)
            params, state, _ = OPT.update(g, state, params, cfg)
            losses.append(float(loss))
        return losses

    def test_adamw_converges(self):
        losses = self._quad_losses(eight_bit=False)
        assert losses[-1] < 0.05 * losses[0]

    def test_8bit_adam_converges(self):
        losses = self._quad_losses(eight_bit=True)
        assert losses[-1] < 0.1 * losses[0]

    def test_8bit_state_is_int8(self):
        cfg = OPT.AdamWConfig(eight_bit=True)
        params = {"w": jnp.zeros((300,), jnp.float32)}
        st = OPT.init(params, cfg)
        assert st["mu"]["w"]["m"]["q"].dtype == jnp.int8

    def test_grad_clip(self):
        cfg = OPT.AdamWConfig(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.zeros((4,), jnp.float32)}
        st = OPT.init(params, cfg)
        g = {"w": jnp.full((4,), 1e6, jnp.float32)}
        new_p, _, met = OPT.update(g, st, params, cfg)
        assert float(met["grad_norm"]) > 1e5
        assert float(jnp.max(jnp.abs(new_p["w"]))) < 1.0


class TestGradCompression:
    def test_roundtrip_error_small(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
        y = GC.compress_decompress(x)
        rel = float(jnp.linalg.norm(x - y) / jnp.linalg.norm(x))
        assert rel < 0.02  # int8 block quantization ~0.5% rms

    def test_error_feedback_accumulates(self):
        """With error feedback the quantization bias stays bounded: the sum
        of compressed grads tracks the sum of true grads."""
        rng = np.random.default_rng(1)
        true_sum = jnp.zeros(256)
        sent_sum = jnp.zeros(256)
        efb = jnp.zeros(256)
        for _ in range(50):
            g = jnp.asarray(rng.normal(size=(256,)), jnp.float32) * 0.01
            true_sum = true_sum + g
            sent = GC.compress_decompress(g + efb)
            efb = (g + efb) - sent
            sent_sum = sent_sum + sent
        rel = float(jnp.linalg.norm(true_sum - sent_sum) /
                    jnp.linalg.norm(true_sum))
        assert rel < 0.05

    def test_train_step_with_compression_runs(self, tmp_path):
        cfg = get_smoke_config("yi_6b")
        tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3), remat=False,
                              lb_ingest=False, grad_compress=True,
                              q_chunk=8, k_chunk=8)
        state = TS.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        step = TS.make_train_step(cfg, tcfg)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab, (2, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        state, m1 = step(state, batch, None)
        state, m2 = step(state, batch, None)
        assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
        assert state["efb"] is not None

    def test_accum_steps_match_full_batch(self):
        cfg = get_smoke_config("yi_6b")
        base = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3), remat=False,
                              lb_ingest=False, q_chunk=8, k_chunk=8)
        acc = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3), remat=False,
                             lb_ingest=False, accum_steps=2, q_chunk=8,
                             k_chunk=8)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab, (4, 16)).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        s0 = TS.init_train_state(jax.random.PRNGKey(0), cfg, base)
        s1 = TS.init_train_state(jax.random.PRNGKey(0), cfg, acc)
        s0b, m0 = TS.make_train_step(cfg, base)(s0, batch, None)
        s1b, m1 = TS.make_train_step(cfg, acc)(s1, batch, None)
        # same data => nearly identical update (fp reassociation tolerance)
        for a, b in zip(jax.tree.leaves(s0b["params"]),
                        jax.tree.leaves(s1b["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)
