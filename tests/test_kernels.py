"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle,
all reached through the DataPlane facade (the only public entry point)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DataPlane, EpochManager, MemberSpec, encode_headers
from repro.core.dataplane import combine_payloads
from repro.core.instance import VirtualLoadBalancer
from repro.kernels import ref
from repro.kernels.dispatch import dispatch_plan
from repro.kernels.lb_route import lb_route


def _tables(n_members=10, weights=None, reconfig=False):
    em = EpochManager(max_members=32)
    weights = weights or {i: 1.0 for i in range(n_members)}
    em.initialize({i: MemberSpec(node_id=i, base_lane=16 * i, lane_bits=i % 4)
                   for i in weights}, weights)
    if reconfig:
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(3)},
                       {i: 1.0 for i in range(3)}, boundary_event=4096)
    return em.device_tables()


def _headers(n, seed=0, corrupt_every=0):
    rng = np.random.default_rng(seed)
    ev = rng.integers(0, 1 << 48, n).astype(np.uint64)
    en = rng.integers(0, 1 << 16, n).astype(np.uint32)
    h = encode_headers(ev, en)
    if corrupt_every:
        h[::corrupt_every, 0] ^= 0x1_0000
    return h


class TestLBRouteKernel:
    @pytest.mark.parametrize("n", [1, 7, 128, 1000, 2048, 5000])
    def test_shape_sweep(self, n):
        t = _tables()
        h = jnp.asarray(_headers(n, seed=n))
        got = lb_route(h, t, interpret=True)
        want = ref.lb_route_ref(h, t)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("block_n", [256, 1024, 2048])
    def test_block_sweep(self, block_n):
        t = _tables(reconfig=True)
        h = jnp.asarray(_headers(3000, seed=block_n, corrupt_every=61))
        got = lb_route(h, t, block_n=block_n, interpret=True)
        want = ref.lb_route_ref(h, t)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    @pytest.mark.parametrize("block_n", [512, 2048])
    def test_multi_instance_sweep(self, block_n):
        """Stacked tables + per-packet instance ids vs the naive per-instance
        oracle (paper §I-C, 4 virtual LBs in one kernel pass)."""
        vlb = VirtualLoadBalancer(max_members=32)
        for k in range(4):
            vlb.instances[k].initialize(
                {i: MemberSpec(node_id=100 * k + i, base_lane=8 * i,
                               lane_bits=(k + i) % 3) for i in range(3 + k)},
                {i: 1.0 for i in range(3 + k)})
        stacked = vlb.device_tables()
        rng = np.random.default_rng(block_n)
        h = jnp.asarray(_headers(3000, seed=block_n, corrupt_every=37))
        iid = jnp.asarray(rng.integers(0, 4, 3000), jnp.int32)
        got = lb_route(h, stacked, iid, block_n=block_n, interpret=True)
        want = ref.lb_route_ref(h, stacked, iid)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_invalid_packets_discarded(self):
        t = _tables()
        h = jnp.asarray(_headers(512, corrupt_every=8))
        r = DataPlane(t, backend="pallas", interpret=True).route(h)
        v = np.asarray(r.valid).astype(np.int32)
        assert int((1 - v).sum()) == 64
        assert (np.asarray(r.member)[v == 0] == -1).all()


class TestDispatchKernel:
    @pytest.mark.parametrize("n,m", [(16, 2), (1000, 7), (4096, 32), (5000, 16)])
    def test_plan_sweep(self, n, m):
        rng = np.random.default_rng(n + m)
        member = jnp.asarray(
            np.where(rng.random(n) < 0.05, -1, rng.integers(0, m, n)).astype(np.int32))
        got_pos, got_counts = dispatch_plan(member, n_members=m, interpret=True)
        want_pos, want_counts = ref.dispatch_plan_ref(member, n_members=m)
        np.testing.assert_array_equal(np.asarray(got_pos), np.asarray(want_pos))
        np.testing.assert_array_equal(np.asarray(got_counts), np.asarray(want_counts))

    @pytest.mark.parametrize("block_n", [128, 512, 1024])
    def test_cross_block_carry(self, block_n):
        """Positions must keep counting across grid steps."""
        member = jnp.asarray(np.zeros(block_n * 3 + 17, np.int32))
        pos, counts = dispatch_plan(member, n_members=4, block_n=block_n,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(pos), np.arange(block_n * 3 + 17))
        assert int(counts[0]) == block_n * 3 + 17

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
    def test_combine_dtypes(self, dtype):
        rng = np.random.default_rng(1)
        member = jnp.asarray(rng.integers(0, 4, 200).astype(np.int32))
        payload = jnp.asarray(rng.normal(size=(200, 16))).astype(dtype)
        pos, _ = dispatch_plan(member, n_members=4, interpret=True)
        buf, occ, dropped = combine_payloads(payload, member, pos,
                                             n_members=4, capacity=64)
        assert buf.dtype == dtype
        assert int(occ.sum()) + int(dropped) == 200


class TestEndToEnd:
    def test_route_then_dispatch_accounting(self):
        """The full data plane: every valid packet lands exactly once."""
        t = _tables(n_members=6, weights={i: float(i + 1) for i in range(6)})
        dp = DataPlane(t, backend="pallas", interpret=True)
        h = jnp.asarray(_headers(4096, corrupt_every=97))
        r = dp.route(h)
        pos, counts = dp.plan(r.member, 6)
        buf, occ, dropped = dp.combine(
            jnp.arange(4096.0)[:, None], r.member, pos, n_members=6,
            capacity=4096)
        assert int(occ.sum()) == int(r.valid.sum())
        assert int(dropped) == 0
        # weighted distribution: member 5 gets ~6x member 0's packets
        c = np.asarray(counts, np.float64)
        assert c[5] / max(c[0], 1) == pytest.approx(6.0, rel=0.35)
