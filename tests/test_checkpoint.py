import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                   "stack": jnp.asarray(rng.normal(size=(3, 5)), jnp.bfloat16)},
        "opt": {"count": jnp.asarray(7, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = _tree()
        ckpt.save(str(tmp_path), 3, t)
        like = jax.tree.map(jnp.zeros_like, t)
        restored, step = ckpt.restore(str(tmp_path), like)
        assert step == 3
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_latest_step(self, tmp_path):
        for s in (1, 5, 12):
            ckpt.save(str(tmp_path), s, _tree(s))
        assert ckpt.latest_step(str(tmp_path)) == 12

    def test_atomicity_tmp_dirs_ignored(self, tmp_path):
        ckpt.save(str(tmp_path), 2, _tree())
        os.makedirs(tmp_path / "step_00000009.tmp")  # torn save
        assert ckpt.latest_step(str(tmp_path)) == 2

    def test_missing_leaf_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(2)})

    def test_async_saver(self, tmp_path):
        s = ckpt.AsyncSaver()
        s.save(str(tmp_path), 4, _tree())
        s.wait()
        restored, step = ckpt.restore(str(tmp_path), _tree())
        assert step == 4

    def test_overwrite_same_step(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
        ckpt.save(str(tmp_path), 1, {"a": jnp.ones(3)})
        restored, _ = ckpt.restore(str(tmp_path), {"a": jnp.zeros(3)})
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.ones(3))
