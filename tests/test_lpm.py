from repro.testing.hypo import given, st

from repro.core import lpm


class TestRangeToPrefixes:
    def test_full_space_is_wildcard(self):
        ps = lpm.range_to_prefixes(0, lpm.EVENT_SPACE)
        assert len(ps) == 1 and ps[0].length == 0

    def test_single_value(self):
        ps = lpm.range_to_prefixes(7, 8)
        assert len(ps) == 1 and ps[0].length == 64 and ps[0].value == 7

    @given(st.integers(0, 2**20), st.integers(0, 2**20))
    def test_exact_cover_property(self, a, b):
        lo, hi = sorted((a, b))
        ps = lpm.range_to_prefixes(lo, hi)
        # prefixes tile [lo, hi) exactly: disjoint, sorted, covering
        ivs = sorted((p.lo, p.hi) for p in ps)
        cur = lo
        for s, e in ivs:
            assert s == cur
            cur = e
        assert cur == hi
        # minimality: adjacent prefixes are never two halves of one block
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            size1, size2 = e1 - s1, e2 - s2
            if size1 == size2 and s1 % (2 * size1) == 0 and s2 == e1:
                assert False, "non-minimal cover"

    @given(st.integers(0, 2**18), st.integers(1, 2**18), st.integers(0, 2**19))
    def test_membership(self, lo, span, probe):
        hi = lo + span
        ps = lpm.range_to_prefixes(lo, hi)
        inside = any(p.matches(probe) for p in ps)
        assert inside == (lo <= probe < hi)


class TestLPMTable:
    def test_longest_prefix_wins(self):
        t = lpm.LPMTable()
        t.set_wildcard("default")
        t.insert_range(1000, 2000, "epoch1")
        assert t.lookup(1500) == "epoch1"
        assert t.lookup(999) == "default"
        assert t.lookup(2000) == "default"

    def test_boundaries_compile(self):
        t = lpm.LPMTable()
        t.set_wildcard("e2")
        t.insert_range(100, 300, "e1")
        segs = t.boundaries()
        # [0,100)->e2, [100,300)->e1, [300,2^64)->e2
        assert segs == [(0, "e2"), (100, "e1"), (300, "e2")]

    @given(st.integers(0, 5000), st.integers(1, 5000),
           st.lists(st.integers(0, 10_000), max_size=20))
    def test_boundaries_equiv_lookup(self, lo, span, probes):
        t = lpm.LPMTable()
        t.set_wildcard("new")
        t.insert_range(lo, lo + span, "old")
        segs = t.boundaries()

        def by_segments(key):
            data = None
            for s, d in segs:
                if key >= s:
                    data = d
            return data

        for p in probes + [lo, lo + span - 1, lo + span]:
            assert by_segments(p) == t.lookup(p)
