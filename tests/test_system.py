"""End-to-end system behaviour: the paper's fig-7 scenario — 5 DAQs stream
through the LB into an elastically changing CN fleet while a model trains on
the reassembled events. This is the integration test tying every subsystem
together (DAQ, segmentation, WAN, LB data plane, control plane, reassembly,
training)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EpochManager, MemberSpec
from repro.data.daq import DAQConfig
from repro.data.pipeline import StreamingPipeline, batches_from_bundles
from repro.data.transport import TransportConfig
from repro.train import optimizer as OPT
from repro.train import train_step as TS


def test_fig7_full_system():
    # --- epoch 1: single CN (paper fig 7c starts with 1) ---
    em = EpochManager(max_members=64)
    em.initialize({0: MemberSpec(node_id=0, lane_bits=2)}, {0: 1.0})
    pipe = StreamingPipeline(
        DAQConfig(n_daqs=5, seq_len=32, mean_bundle_bytes=15_000, seed=7),
        TransportConfig(reorder_window=24, seed=7), em)
    payloads = list(pipe.pump(15))

    # --- epoch 2: switch to CN 4,5,6 (add nodes, drop CN-0) ---
    b1 = pipe.fleet.event_number + 30
    em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in (4, 5, 6)},
                   {i: 1.0 for i in (4, 5, 6)}, boundary_event=b1)
    payloads += pipe.pump(25)

    # --- epoch 3: all 10 CNs, CN-5 weighted 2x ---
    b2 = pipe.fleet.event_number + 30
    em.reconfigure({i: MemberSpec(node_id=i, lane_bits=2) for i in range(10)},
                   {i: (2.0 if i == 5 else 1.0) for i in range(10)},
                   boundary_event=b2)
    payloads += pipe.pump(60)

    # paper's acceptance criteria
    assert pipe.stats.n_discarded == 0, "hit-less switching must not drop"
    emap = pipe.event_member_map()
    assert all(len(m) == 1 for m in emap.values()), "events must not split"

    # quiesce the drained epochs; routing for current epoch unaffected
    em.quiesce(0)
    em.quiesce(1)

    # --- the reassembled stream trains a model ---
    cfg = get_smoke_config("stablelm_3b")
    batches = batches_from_bundles(payloads, seq_len=32, batch_size=4)
    assert len(batches) >= 3
    tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=5e-3, warmup_steps=1),
                          remat=False, lb_ingest=False, q_chunk=8, k_chunk=8)
    state = TS.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(TS.make_train_step(cfg, tcfg))
    losses = []
    for b in batches[:6]:
        t = jnp.asarray(b % cfg.vocab)
        state, metrics = step(state, {"tokens": t, "labels": t}, None)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
