"""Per-arch smoke tests (reduced same-family configs) + numerics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import shapes as SH
from repro.models import mamba2 as M2
from repro.models import model as M

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.family == "audio":
        batch["embeds"] = jnp.asarray(rng.normal(size=(b, t, cfg.d_model)),
                                      jnp.float32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)))
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)))
        batch["tokens"], batch["labels"] = toks, toks
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vision_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmokePerArch:
    def test_forward_and_train_step(self, arch):
        """One forward + one loss/grad step on CPU: shapes + no NaNs."""
        cfg = get_smoke_config(arch)
        params = M.init_params(RNG, cfg)
        batch = _batch(cfg)
        logits, aux = M.forward(params, batch, cfg, remat=False, q_chunk=8,
                                k_chunk=8)
        assert logits.shape == (2, 16, cfg.vocab)
        assert bool(jnp.isfinite(logits).all())
        (loss, met), grads = jax.value_and_grad(
            lambda p: M.train_loss(p, batch, cfg, remat=False, q_chunk=8,
                                   k_chunk=8), has_aux=True)(params)
        assert bool(jnp.isfinite(loss))
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0

    def test_full_config_exact(self, arch):
        """The registered full config matches the assignment table."""
        cfg = get_config(arch)
        table = {
            "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
            "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
            "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
            "granite-20b": (52, 6144, 48, 1, 24576, 49152),
            "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
            "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
            "yi-6b": (32, 4096, 32, 4, 11008, 64000),
            "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
            "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
            "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        }[cfg.name]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == table


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ["yi_6b", "mixtral_8x22b", "zamba2_2_7b",
                                      "rwkv6_7b", "llama_3_2_vision_90b"])
    def test_prefill_decode_matches_forward(self, arch):
        cfg = get_smoke_config(arch)
        if cfg.family == "moe":
            cfg = cfg.with_(capacity_factor=100.0)  # drop-free => exact
        params = M.init_params(RNG, cfg)
        b, t = 2, 12
        batch = _batch(cfg, b, t)
        full, _ = M.forward(params, batch, cfg, remat=False, q_chunk=8, k_chunk=8)
        state = M.init_decode_state(cfg, b, max_len=32)
        pre = {k: v[:, :t - 1] if k in ("tokens", "embeds") else v
               for k, v in batch.items() if k != "labels"}
        lp, state = M.prefill(params, pre, state, cfg, q_chunk=8, k_chunk=8)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(full[:, t - 2]),
                                   rtol=2e-4, atol=2e-4)
        ld, state = M.decode_step(params, batch["tokens"][:, t - 1], state, cfg)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(full[:, t - 1]),
                                   rtol=2e-4, atol=2e-4)

    def test_swa_ring_cache_decode(self):
        """Decode past the SWA window: ring cache must evict correctly."""
        cfg = get_smoke_config("mixtral_8x22b").with_(capacity_factor=100.0)
        assert cfg.swa_window == 16
        params = M.init_params(RNG, cfg)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 40)))
        # reference: full forward (SWA mask) at position 39
        full, _ = M.forward(params, {"tokens": toks}, cfg, remat=False,
                            q_chunk=8, k_chunk=8)
        state = M.init_decode_state(cfg, 1, max_len=64)  # ring size = window
        _, state = M.prefill(params, {"tokens": toks[:, :30]}, state, cfg,
                             q_chunk=8, k_chunk=8)
        out = None
        for i in range(30, 40):
            out, state = M.decode_step(params, toks[:, i], state, cfg)
            # feeding token i produces logits for position i
        np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, 39]),
                                   rtol=2e-4, atol=2e-4)


class TestChunkEquivalence:
    def test_attention_chunk_invariance(self):
        cfg = get_smoke_config("yi_6b")
        params = M.init_params(RNG, cfg)
        batch = _batch(cfg, 2, 24)
        l1, _ = M.forward(params, batch, cfg, remat=False, q_chunk=24, k_chunk=24)
        l2, _ = M.forward(params, batch, cfg, remat=False, q_chunk=8, k_chunk=4)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                                   atol=2e-4)

    def test_rwkv_chunked_vs_scan(self):
        cfg = get_smoke_config("rwkv6_7b")
        params = M.init_params(RNG, cfg)
        batch = _batch(cfg, 2, 33)
        l1, _ = M.forward(params, batch, cfg, remat=False, rwkv_chunk=1)
        l2, _ = M.forward(params, batch, cfg, remat=False, rwkv_chunk=8)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-3,
                                   atol=1e-3)

    def test_mamba2_chunk_invariance_and_recurrence(self):
        cfg = get_smoke_config("zamba2_2_7b")
        p = M2.mamba2_init(jax.random.PRNGKey(2), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 29, cfg.d_model)),
                        jnp.float32) * 0.1
        y1, s1 = M2.mamba2_block(p, x, cfg, chunk=29)
        y2, s2 = M2.mamba2_block(p, x, cfg, chunk=4)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        # against the per-token recurrence oracle
        state, ys = None, []
        for i in range(29):
            y, state = M2.mamba2_block(p, x[:, i:i + 1], cfg, state=state, chunk=1)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(y1),
                                   np.asarray(jnp.concatenate(ys, 1)), atol=1e-5)


class TestShapeGrid:
    def test_cell_accounting(self):
        """40 nominal cells; skips documented in DESIGN.md §4."""
        total, runnable = 0, 0
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for shape in SH.SHAPES:
                total += 1
                if SH.skip_reason(cfg, shape) is None:
                    runnable += 1
        assert total == 40
        assert runnable == 32

    def test_skip_reasons(self):
        hubert = get_config("hubert_xlarge")
        assert SH.skip_reason(hubert, "decode_32k")
        assert SH.skip_reason(hubert, "long_500k")
        assert SH.skip_reason(get_config("yi_6b"), "long_500k")
        assert SH.skip_reason(get_config("mixtral_8x22b"), "long_500k") is None
        assert SH.skip_reason(get_config("rwkv6_7b"), "long_500k") is None


class TestMoEDispatchGroups:
    def test_grouped_equals_ungrouped_dropfree(self):
        """The perf-variant grouped dispatch is semantics-preserving."""
        cfg = get_smoke_config("mixtral_8x22b").with_(capacity_factor=100.0)
        params = M.init_params(RNG, cfg)
        batch = _batch(cfg, 4, 16)
        l1, _ = M.forward(params, batch, cfg, remat=False, q_chunk=8, k_chunk=8)
        l2, _ = M.forward(params, batch, cfg.with_(moe_dispatch_groups=4),
                          remat=False, q_chunk=8, k_chunk=8)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))

    def test_group_capacity_drops_accounted(self):
        from repro.models import moe as MOE
        cfg = get_smoke_config("mixtral_8x22b").with_(
            moe_dispatch_groups=4, capacity_factor=0.5)
        p = MOE.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16, cfg.d_model)),
                        jnp.float32)
        y, aux = MOE.moe_ffn(p, x, cfg)
        assert bool(jnp.isfinite(y).all())
        assert int(aux["dropped"]) > 0  # cf 0.5 must overflow

    def test_indivisible_group_count_falls_back(self):
        cfg = get_smoke_config("mixtral_8x22b").with_(moe_dispatch_groups=7)
        params = M.init_params(RNG, cfg)
        batch = _batch(cfg, 2, 15)  # 30 tokens % 7 != 0 -> g=1 fallback
        logits, _ = M.forward(params, batch, cfg, remat=False, q_chunk=8,
                              k_chunk=8)
        assert bool(jnp.isfinite(logits).all())
