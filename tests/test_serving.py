import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("yi_6b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, ServeConfig(n_replicas=2, lane_bits=1,
                                          max_len=64), params)


class TestServing:
    def test_requests_complete(self, engine):
        rng = np.random.default_rng(0)
        reqs = [engine.submit(rng.integers(0, 256, int(rng.integers(4, 10))),
                              max_new_tokens=6) for _ in range(9)]
        engine.run_until_done(300)
        assert all(r.done for r in reqs)
        assert all(len(r.output) == 6 for r in reqs)

    def test_front_door_routes_by_calendar(self, engine):
        """Requests spread across replicas via the LB (not round-robin code)."""
        assert len(engine.stats["routed"]) == 2

    def test_greedy_determinism(self, engine):
        a = engine.submit(np.arange(5), max_new_tokens=5)
        engine.run_until_done(100)
        b = engine.submit(np.arange(5), max_new_tokens=5)
        engine.run_until_done(100)
        assert a.output == b.output

    def test_lane_isolation(self, engine):
        """Two concurrent requests in different lanes don't corrupt each
        other: outputs equal the solo runs."""
        p1, p2 = np.arange(6), np.arange(6)[::-1].copy()
        solo1 = engine.submit(p1, max_new_tokens=5); engine.run_until_done(100)
        solo2 = engine.submit(p2, max_new_tokens=5); engine.run_until_done(100)
        r1 = engine.submit(p1, max_new_tokens=5)
        r2 = engine.submit(p2, max_new_tokens=5)
        engine.run_until_done(200)
        assert r1.output == solo1.output
        assert r2.output == solo2.output
