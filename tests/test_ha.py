"""controld HA: lease arbiter semantics, WAL-shipped warm standbys,
client-driven failover, idempotent resend across takeover, and the
leader_failover chaos gate."""
import dataclasses

import pytest

from repro.controld import (ControldClient, ControldError, FailoverTransport,
                            FileLeaseStore, HACluster, Journal, LeaseStore,
                            NodeTransport, RetryPolicy, SocketClient,
                            SocketServer, TransportError)
from repro.controld import messages as M
from repro.controld.replication import STALE_GENERATION
from repro.controld.transport import NOT_LEADER
from repro.simnet import Simulator, get_scenario
from repro.testing.faults import FaultInjector, FrozenClock, InjectedCrash

DKW = dict(n_instances=1, lease_s=1e9, epoch_horizon=64, max_members=16)


def _cluster(clock, term_s=1.0, n_nodes=2, store=None, **kw):
    d = dict(DKW)
    d.update(kw)
    return HACluster(n_nodes=n_nodes, clock=clock, term_s=term_s,
                     store=store, daemon_kwargs=d)


def _setup(client, n_members=4):
    token = client.reserve(policy="proportional")["token"]
    for m in range(n_members):
        client.register(token, member_id=m, node_id=m, lane_bits=1)
    client.tick(current_event=0)
    return token


class TestLeaseArbiter:
    def test_claim_free_then_renewal_keeps_generation(self):
        clk = FrozenClock()
        store = LeaseStore(term_s=1.0, clock=clk)
        got = store.claim("a")
        assert got.holder == "a" and got.generation == 1
        clk.advance(0.5)
        renewed = store.claim("a")
        assert renewed.generation == 1 and renewed.expires == 1.5

    def test_held_lease_blocks_rival_until_expiry(self):
        clk = FrozenClock()
        store = LeaseStore(term_s=1.0, clock=clk)
        store.claim("a")
        assert store.claim("b") is None          # still held
        clk.advance(1.0)                          # expires <= now: lapsed
        got = store.claim("b")
        assert got.holder == "b" and got.generation == 2

    def test_release_frees_without_generation_bump(self):
        clk = FrozenClock()
        store = LeaseStore(term_s=1.0, clock=clk)
        store.claim("a")
        store.release("a")
        st = store.read()
        assert st.holder == "" and st.generation == 1
        # next claim is an ownership change: generation bumps
        assert store.claim("b").generation == 2

    def test_release_by_non_holder_is_a_noop(self):
        clk = FrozenClock()
        store = LeaseStore(term_s=1.0, clock=clk)
        store.claim("a")
        store.release("b")
        assert store.read().holder == "a"

    def test_file_store_shared_between_processes(self, tmp_path):
        clk = FrozenClock()
        path = str(tmp_path / "lease.json")
        a = FileLeaseStore(path, term_s=1.0, clock=clk)
        b = FileLeaseStore(path, term_s=1.0, clock=clk)
        a.claim("a")
        # the rival store reads the same file: blocked, then takes over
        assert b.read().holder == "a"
        assert b.claim("b") is None
        clk.advance(1.5)
        got = b.claim("b")
        assert got.holder == "b" and got.generation == 2
        assert a.read().generation == 2

    def test_file_store_tolerates_garbage(self, tmp_path):
        path = str(tmp_path / "lease.json")
        with open(path, "w") as f:
            f.write("{not json")
        store = FileLeaseStore(path, term_s=1.0, clock=FrozenClock())
        st = store.read()
        assert st.holder == "" and st.generation == 0
        assert store.claim("a").generation == 1


class TestReplication:
    def test_standby_digest_tracks_leader(self):
        clk = FrozenClock()
        cluster = _cluster(clk)
        leader = cluster.leader()
        client = ControldClient(NodeTransport(leader), client_id="t")
        token = _setup(client)
        for k in range(8):
            client.send_state(token, k % 4, fill=0.25 + 0.05 * k)
        (standby,) = cluster.standbys()
        assert leader.daemon.journal.seq == standby.daemon.journal.seq
        assert (leader.daemon.state_digest()
                == standby.daemon.state_digest())
        assert leader.replicator.lag() == 0

    def test_standby_rejects_mutations_with_not_leader(self):
        cluster = _cluster(FrozenClock())
        (standby,) = cluster.standbys()
        reply = NodeTransport(standby).call(M.Reserve())
        assert not reply.ok and NOT_LEADER in reply.error
        # reads still answer everywhere (Status is not mutating)
        st = NodeTransport(standby).call(M.Status())
        assert st.ok and st.data["ha"]["role"] == "standby"

    def test_status_reports_ha_identity(self):
        cluster = _cluster(FrozenClock())
        leader = cluster.leader()
        st = NodeTransport(leader).call(M.Status())
        assert st.data["ha"] == {"node": "cd0", "role": "leader",
                                 "generation": 1}

    def test_dead_standby_skipped_then_caught_up_on_revive(self):
        clk = FrozenClock()
        cluster = _cluster(clk)
        leader = cluster.leader()
        client = ControldClient(NodeTransport(leader), client_id="t")
        token = _setup(client)
        (standby,) = cluster.standbys()
        standby.kill()
        # a dead standby must not freeze the leader
        for k in range(6):
            client.send_state(token, k % 4, fill=0.5)
        assert not leader.replicator.peers["cd1"].alive
        # revive = fresh empty journal; attach streams the full backlog
        cluster.revive(standby)
        assert standby.daemon.journal.seq == leader.daemon.journal.seq
        assert (standby.daemon.state_digest()
                == leader.daemon.state_digest())
        assert leader.replicator.lag() == 0

    def test_stale_generation_shipment_fenced(self):
        clk = FrozenClock()
        cluster = _cluster(clk)
        node1 = cluster.nodes[1]
        node1.generation = 5  # saw a newer leader
        reply = NodeTransport(node1).call(
            M.ReplicateEntries(leader="cd0", generation=1, entries=()))
        assert not reply.ok and STALE_GENERATION in reply.error


class TestFailover:
    def _failover_client(self, cluster, clk, client_id="t"):
        retry = RetryPolicy(base_s=0.2, cap_s=0.5, max_elapsed_s=120.0,
                            seed=0)
        ft = FailoverTransport(cluster.client_endpoints(), retry=retry,
                               sleep=clk.advance, clock=clk)
        return ControldClient(ft, client_id=client_id)

    def test_retrying_client_alone_drives_takeover(self):
        clk = FrozenClock()
        cluster = _cluster(clk, term_s=1.0)
        client = self._failover_client(cluster, clk)
        token = _setup(client)
        pre_kill = cluster.leader().daemon.state_digest()
        cluster.kill_leader()
        # no external coordinator: the retrying heartbeat promotes cd1
        out = client.send_state(token, 0, fill=0.5)
        assert out["lease_expires"] > 0
        successor = cluster.leader()
        assert successor.node_id == "cd1"
        assert successor.generation == 2       # ownership change fenced
        assert successor.promotions == 1
        # the successor resumed from the dead leader's exact state
        assert successor.promoted_digest == pre_kill

    def test_session_survives_takeover(self):
        clk = FrozenClock()
        cluster = _cluster(clk, term_s=1.0)
        client = self._failover_client(cluster, clk)
        token = _setup(client)
        cluster.kill_leader()
        # the token minted by the dead leader is honoured by the successor
        for k in range(4):
            client.send_state(token, k, fill=0.25)
        client.tick(current_event=1)
        assert cluster.leader().daemon.sessions[token].started

    def test_partitioned_ex_leader_steps_down(self):
        clk = FrozenClock()
        cluster = _cluster(clk, term_s=1.0)
        old = cluster.leader()
        # the leader goes silent (no renewals) without dying; its lease
        # lapses and the standby claims it
        clk.advance(1.5)
        cluster.nodes[1].step()
        assert cluster.nodes[1].role == "leader"
        assert cluster.nodes[1].generation == 2
        # the ex-leader's next mutating message makes it re-check the
        # arbiter, discover the loss, and answer NOT_LEADER
        reply = NodeTransport(old).call(M.Reserve())
        assert not reply.ok and NOT_LEADER in reply.error
        assert old.role == "standby"

    def test_file_lease_store_drives_in_proc_failover(self, tmp_path):
        clk = FrozenClock()
        store = FileLeaseStore(str(tmp_path / "lease.json"), term_s=1.0,
                               clock=clk)
        cluster = _cluster(clk, term_s=1.0, store=store)
        client = self._failover_client(cluster, clk)
        token = _setup(client)
        cluster.kill_leader()
        client.send_state(token, 0, fill=0.5)
        assert cluster.leader().node_id == "cd1"
        assert store.read().holder == "cd1"


class TestIdempotentResend:
    """SendStateBatch (or any mutation) racing leader death must be
    fully-applied-or-fully-absent, and the client's stamped request id
    must make the resend against the successor safe either way."""

    def _primed(self, clk, crash_at):
        cluster = _cluster(clk, term_s=1.0)
        leader = cluster.leader()
        client = ControldClient(NodeTransport(leader), client_id="t")
        token = _setup(client)
        leader.faults = FaultInjector(seed=0, crash_at=crash_at)
        return cluster, leader, token

    def _promote_standby(self, cluster, clk):
        clk.advance(1.5)
        (standby,) = cluster.standbys()
        standby.step()
        assert standby.role == "leader"
        return standby

    def test_crash_before_ship_is_fully_absent_and_resend_applies_once(self):
        clk = FrozenClock()
        cluster, leader, token = self._primed(
            clk, {"ha.leader.before_ship": 1})
        msg = M.SendStateBatch(token=token, member_ids=(0, 1, 2, 3),
                               fills=(0.9, 0.9, 0.9, 0.9),
                               rates=(1.0,) * 4, healthy=(True,) * 4,
                               req="t:99")
        seq_before = leader.daemon.journal.seq
        with pytest.raises(InjectedCrash):
            NodeTransport(leader).call(msg)
        # the leader journaled it but died before shipping: the batch is
        # fully absent from the surviving replica
        leader.kill()
        successor = self._promote_standby(cluster, clk)
        assert successor.daemon.journal.seq == seq_before
        # the resend applies exactly once on the successor
        reply = NodeTransport(successor).call(msg)
        assert reply.ok
        assert successor.daemon.journal.seq == seq_before + 1
        sess = successor.daemon.sessions[token]
        assert float(sess.lanes.fill[0]) == pytest.approx(0.9)

    def test_crash_after_ship_is_fully_applied_and_resend_dedupes(self):
        clk = FrozenClock()
        cluster, leader, token = self._primed(
            clk, {"ha.leader.after_ship": 1})
        msg = M.SendStateBatch(token=token, member_ids=(0, 1, 2, 3),
                               fills=(0.8, 0.8, 0.8, 0.8),
                               rates=(1.0,) * 4, healthy=(True,) * 4,
                               req="t:77")
        with pytest.raises(InjectedCrash):
            NodeTransport(leader).call(msg)
        leader.kill()
        successor = self._promote_standby(cluster, clk)
        # the shipment landed before the crash: fully applied on the
        # survivor, and the req-id cache (rebuilt by the replay-path
        # apply) answers the resend WITHOUT a second application
        seq_applied = successor.daemon.journal.seq
        assert float(successor.daemon.sessions[token]
                     .lanes.fill[0]) == pytest.approx(0.8)
        reply = NodeTransport(successor).call(msg)
        assert reply.ok
        assert successor.daemon.journal.seq == seq_applied

    def test_lapsed_lease_rejection_stamp_survives_takeover(self):
        clk = FrozenClock()
        cluster = _cluster(clk, term_s=1.0, lease_s=5.0)
        leader = cluster.leader()
        client = ControldClient(NodeTransport(leader), client_id="t")
        token = _setup(client)
        clk.advance(20.0)  # every CN lease lapses
        with pytest.raises(ControldError) as e_leader:
            client.send_state(token, 0, fill=0.5)
        assert "lease lapsed at" in str(e_leader.value)
        leader.kill()
        successor = self._promote_standby(cluster, clk)
        with pytest.raises(ControldError) as e_succ:
            ControldClient(NodeTransport(successor),
                           client_id="t2").send_state(token, 0, fill=0.5)
        # identical lapsed-at stamp: the lease table replicated exactly
        stamp = str(e_leader.value).split(" (now")[0]
        assert stamp in str(e_succ.value)


class TestSocketHANode:
    def test_ha_node_serves_a_socket_endpoint(self):
        from repro.controld import ControlDaemon
        clk = FrozenClock()
        store = LeaseStore(term_s=1e9, clock=clk)
        from repro.controld.ha import HANode
        node = HANode("cd0", ControlDaemon(clock=clk, journal=Journal(),
                                           **DKW), store, clock=clk)
        node.step()
        assert node.role == "leader"
        server = SocketServer(node)
        host, port = server.start()
        try:
            client = ControldClient(SocketClient(host, port), client_id="t")
            token = _setup(client, n_members=2)
            out = client.send_state(token, 0, fill=0.5)
            assert out["lease_expires"] > 0
            assert client.status()["ha"]["role"] == "leader"
            client.transport.close()
        finally:
            server.stop()


class TestLeaderFailoverScenario:
    def test_chaos_gates_pass_under_leader_kill(self):
        sc = get_scenario("leader_failover")
        sim = Simulator(sc.build_config(steps=45), dataclasses.replace(sc))
        r = sim.run()
        assert r.violations == []
        assert r.ha_failovers >= 1
        assert sim.ha_revivals >= 1
        # zero lost bundles: the data plane kept forwarding throughout
        assert r.bundles_completed == r.bundles_sent
        assert r.bundles_timed_out == 0
        # takeover bounded by ~one lease term
        term = sim._ha_term_s()
        assert all(d <= 1.25 * term for d in r.ha_failover_durations)
        # after the post-failover revive, replication is current again
        lead = sim.cluster.leader()
        assert lead.replicator.lag() == 0

    def test_failover_run_matches_never_killed_control(self):
        sc = get_scenario("leader_failover")
        chaos = Simulator(sc.build_config(steps=45),
                          dataclasses.replace(sc)).run()

        def control_hook(sim, step):
            # same workload shape (mute + drain + re-register), no kill
            lo, hi = sim.cfg.steps // 3, 2 * sim.cfg.steps // 3
            if step == lo:
                sim.muted.add(1)
            if step == hi:
                sim.muted.discard(1)
                sim.reregister(1)

        control = Simulator(sc.build_config(steps=45),
                            dataclasses.replace(sc, on_step=control_hook)
                            ).run()
        assert control.violations == [] and control.ha_failovers == 0
        # the kill is invisible to delivery: both runs complete everything
        assert chaos.bundles_completed == chaos.bundles_sent
        assert control.bundles_completed == control.bundles_sent
        assert chaos.bundles_sent == control.bundles_sent

    def test_deterministic_failover_schedule(self):
        sc = get_scenario("leader_failover")
        a = Simulator(sc.build_config(steps=30), dataclasses.replace(sc))
        ra = a.run()
        b = Simulator(sc.build_config(steps=30), dataclasses.replace(sc))
        rb = b.run()
        assert ra.ha_failovers == rb.ha_failovers
        assert ra.ha_failover_durations == rb.ha_failover_durations
        assert (a.daemon.state_digest() == b.daemon.state_digest())
