"""Per-bundle tracing: sampling determinism, golden Perfetto bytes,
host/fused span parity, controld trace propagation, critical-path
reconciliation, and exemplar cross-referencing."""
import json

import numpy as np
import pytest

from repro.controld import (ControlDaemon, ControldClient, InProcTransport,
                            SocketClient, SocketServer)
from repro.simnet import SimConfig, Simulator, get_scenario
from repro.telemetry.registry import LATENCY_BUCKETS_S
from repro.telemetry.trace import (STAGES, TraceBuffer, TraceConfig,
                                   bundle_key, mix64, parse_trace_id,
                                   trace_id)
from repro.telemetry.traceview import (critical_path, reconcile,
                                       stage_decomposition, summary_json)

LOOP_KW = dict(triggers_per_step=16, n_daqs=2, n_members=4,
               mean_bundle_bytes=6_000)


def _tb(**cfg) -> TraceBuffer:
    return TraceBuffer(TraceConfig(**cfg))


class TestIds:
    def test_trace_id_roundtrip(self):
        for k in (0, 1, 0xDEADBEEF, (1 << 62) | 17, 2**64 - 1):
            assert parse_trace_id(trace_id(k)) == k
            assert len(trace_id(k)) == 16

    def test_bundle_key_packs_event_and_daq(self):
        ks = bundle_key([5, 5, 9], [0, 3, 1])
        assert ks.dtype == np.uint64
        assert [int(k) >> 16 for k in ks] == [5, 5, 9]
        assert [int(k) & 0xFFFF for k in ks] == [0, 3, 1]

    def test_stage_registry_is_stable_and_extensible(self):
        tb = _tb()
        assert [tb.stage_id(s) for s in STAGES] == list(range(len(STAGES)))
        sid = tb.stage_id("controld.tick")
        assert sid == len(STAGES)
        assert tb.stage_id("controld.tick") == sid   # idempotent


class TestSampling:
    def test_head_sampling_is_a_pure_function_of_event_and_seed(self):
        keys = bundle_key(np.arange(4096), np.zeros(4096, np.int64))
        m1 = _tb(head_rate=0.25, seed=7).head_sampled(keys)
        m2 = _tb(head_rate=0.25, seed=7).head_sampled(keys)
        m3 = _tb(head_rate=0.25, seed=8).head_sampled(keys)
        assert (m1 == m2).all()
        assert not (m1 == m3).all()
        assert 0.15 < m1.mean() < 0.35          # ~rate, mix64 is uniform

    def test_same_event_different_daq_share_fate(self):
        # sampling hashes the *event*, so a bundle's packet copies across
        # DAQs are kept or dropped together
        tb = _tb(head_rate=0.5, seed=3)
        ev = np.repeat(np.arange(512), 4)
        ks = bundle_key(ev, np.tile(np.arange(4), 512))
        m = tb.head_sampled(ks)
        assert (m.reshape(512, 4) == m.reshape(512, 4)[:, :1]).all()

    def test_tail_reservoir_keeps_k_slowest_deterministically(self):
        rng = np.random.default_rng(0)
        ks = bundle_key(np.arange(1000), np.zeros(1000, np.int64))
        e2e = rng.uniform(1e-4, 1e-1, 1000)
        want = ks[np.lexsort((ks, e2e))[::-1][:16]]
        for perm_seed in (1, 2):
            tb = _tb(head_rate=0.0, tail_k=16)
            order = np.random.default_rng(perm_seed).permutation(1000)
            for i in order:           # append order must not matter
                tb.complete_window(ks[i:i + 1], [0.0], e2e[i:i + 1])
            assert sorted(int(k) for k in tb.tail_keys()) == \
                sorted(int(k) for k in want)

    def test_head_zero_retains_only_the_tail(self):
        tb = _tb(head_rate=0.0, tail_k=4)
        ks = bundle_key(np.arange(32), np.zeros(32, np.int64))
        e2e = np.linspace(1e-3, 2e-3, 32)
        tb.record_window("uplink", ks, np.zeros(32), e2e)
        tb.complete_window(ks, np.zeros(32), e2e)
        tb.end_window()
        kept = tb.spans()["key"]
        assert sorted(set(int(k) for k in kept)) == \
            sorted(int(k) for k in ks[-4:])

    def test_compaction_preserves_retained_and_incomplete(self):
        tb = _tb(head_rate=0.0, tail_k=2, compact_every=1)
        ks = bundle_key(np.arange(8), np.zeros(8, np.int64))
        e2e = np.linspace(1e-3, 8e-3, 8)
        tb.record_window("uplink", ks, np.zeros(8), e2e)
        # one bundle never completes -> its spans must survive compaction
        tb.complete_window(ks[:7], np.zeros(7), e2e[:7])
        tb.end_window()                          # triggers _compact
        buffered = set(int(k) for c in tb._chunks for k in c[1])
        assert int(ks[7]) in buffered   # incomplete: kept until it completes
        assert int(ks[0]) not in buffered        # completed, unretained
        exported = set(int(k) for k in tb.spans()["key"])
        assert exported == {int(ks[5]), int(ks[6])}  # tail top-2 only


class TestGoldenPerfetto:
    def _small(self) -> TraceBuffer:
        tb = _tb(head_rate=1.0, tail_k=4)
        ks = bundle_key([1, 2], [0, 1])
        tb.record_window("emit_wait", ks, [0.0, 0.001], [0.002, 0.003])
        tb.record_window("uplink", ks, [0.002, 0.003], [0.004, 0.0055],
                         pid=np.asarray([0, 1], np.uint64), aux=[0, 1])
        tb.complete_window(ks, [0.0, 0.001], [0.01, 0.02])
        tb.end_window()
        return tb

    def test_golden_bytes(self):
        got = self._small().to_perfetto_json()
        # canonical order: bundle key, then pid (packet copies before the
        # BUNDLE_PID-namespace bundle-level spans), keys sorted, compact
        want = (
            '{"displayTimeUnit":"ns","traceEvents":['
            '{"args":{"aux":0,"daq":0,"event":1,'
            '"trace_id":"0000000000010000"},'
            '"cat":"bundle","dur":2000.0,"name":"uplink","ph":"X",'
            '"pid":65536,"tid":1,"ts":2000.0},'
            '{"args":{"daq":0,"event":1,"trace_id":"0000000000010000"},'
            '"cat":"bundle","dur":2000.0,"name":"emit_wait","ph":"X",'
            '"pid":65536,"tid":0,"ts":0.0},'
            '{"args":{"aux":1,"daq":1,"event":2,'
            '"trace_id":"0000000000020001"},'
            '"cat":"bundle","dur":2500.0,"name":"uplink","ph":"X",'
            '"pid":131073,"tid":2,"ts":3000.0},'
            '{"args":{"daq":1,"event":2,"trace_id":"0000000000020001"},'
            '"cat":"bundle","dur":2000.0,"name":"emit_wait","ph":"X",'
            '"pid":131073,"tid":0,"ts":1000.0}]}').encode()
        assert got == want

    def test_export_is_valid_trace_event_json(self):
        doc = json.loads(self._small().to_perfetto_json())
        assert set(doc) == {"displayTimeUnit", "traceEvents"}
        for ev in doc["traceEvents"]:
            assert ev["ph"] == "X"
            assert ev["dur"] >= 0 and isinstance(ev["pid"], int)
            assert parse_trace_id(ev["args"]["trace_id"]) == ev["pid"]

    def test_summary_roundtrip(self):
        tb = self._small()
        tb2 = TraceBuffer.from_summary(
            json.loads(json.dumps(tb.to_summary())))
        assert tb2.to_perfetto_json() == tb.to_perfetto_json()
        a, b = tb.completions(), tb2.completions()
        for x, y in zip(a, b):
            assert np.array_equal(x, y)


def _run(scenario: str, engine: str, steps: int = 24, **kw) -> Simulator:
    sc = get_scenario(scenario)
    cfg = sc.build_config(steps=steps, seed=0, engine=engine, trace=True,
                          **kw)
    sim = Simulator(cfg, scenario=sc)
    r = sim.run()
    assert not r.violations, r.violations
    assert r.engine == engine, (r.engine, engine)
    return sim


class TestEngineParity:
    """The fused engine materializes spans post-hoc from the superblock's
    returned arrays; the host engine records inline. Identical span sets
    (ids exact, times to float-association tolerance) on gated scenarios."""

    @pytest.mark.parametrize("scenario", ["baseline", "straggler"])
    def test_identical_span_sets(self, scenario):
        sh = _run(scenario, "host").trace
        sf = _run(scenario, "fused").trace
        a, b = sh.spans(), sf.spans()
        assert len(a["key"]) == len(b["key"]) > 0
        for f in ("stage", "key", "pid", "aux"):
            assert np.array_equal(a[f], b[f]), f
        for f in ("t0", "t1"):
            assert np.allclose(a[f], b[f], rtol=1e-9, atol=1e-12), f
        ka, _, da = sh.completions()
        kb, _, db = sf.completions()
        assert np.array_equal(np.sort(ka), np.sort(kb))
        assert np.allclose(np.sort(da), np.sort(db), rtol=1e-9, atol=1e-12)

    def test_sampled_parity(self):
        sh = _run("baseline", "host", trace_sample=0.25, trace_tail_k=8)
        sf = _run("baseline", "fused", trace_sample=0.25, trace_tail_k=8)
        a, b = sh.trace.spans(), sf.trace.spans()
        assert np.array_equal(a["key"], b["key"])
        assert np.array_equal(sh.trace.tail_keys(), sf.trace.tail_keys())

    def test_fused_tracing_is_retrace_free(self):
        from repro.simnet import fused
        t0 = fused.FUSED_TRACES
        Simulator(SimConfig(steps=16, engine="fused", **LOOP_KW)).run()
        base_traces = fused.FUSED_TRACES - t0
        Simulator(SimConfig(steps=16, engine="fused", trace=True,
                            **LOOP_KW)).run()
        assert fused.FUSED_TRACES - t0 == base_traces, \
            "enabling tracing retraced the fused superblock"


class TestCriticalPath:
    def test_reconciles_under_one_percent(self):
        tb = _run("baseline", "fused").trace
        for pct in (50.0, 99.0):
            d = stage_decomposition(tb, pct)
            assert d is not None
            assert d["reconcile_rel_err"] < 0.01
            assert d["dominant"] in d["stages"]
        ks, te, td = tb.completions()
        ssum, e2e, rel = reconcile(tb, int(ks[0]))
        assert rel < 0.01

    def test_path_partitions_the_bundle_interval(self):
        tb = _run("baseline", "host").trace
        ks, te, td = tb.completions()
        path = critical_path(tb, int(ks[0]))
        assert [s for s, _ in path if s != "emit_wait"][0] == "uplink"
        assert path[-1][0] == "reassembly"
        assert all(dur >= -1e-12 for _, dur in path)

    def test_summary_json_shape(self):
        tb = _run("baseline", "host").trace
        s = summary_json(tb, (99.0,))
        assert s["n_completions"] > 0
        p99 = s["percentiles"]["p99"]
        assert parse_trace_id(p99["trace_id"]) >= 0
        assert p99["dominant"] in p99["stages"]


class TestControldPropagation:
    """Trace ids ride the message envelope; the daemon records one span
    per traced message. InProc and socket must agree on everything but
    wall-clock durations."""

    def _play(self, transport, tb):
        client = ControldClient(transport)
        client.trace = trace_id(101)
        token = client.reserve(policy="proportional")["token"]
        client.register(token, member_id=0, node_id=0, lane_bits=1)
        client.trace = trace_id(202)
        client.send_state(token, member_id=0, fill=0.4)
        with pytest.raises(Exception):
            client.send_state("bogus", member_id=0, fill=0.4)  # rejected
        client.trace = ""                       # untraced -> no span
        client.tick(current_event=500)
        sp = tb.spans()
        return [(tb.stage_names[int(s)], int(k), int(a))
                for s, k, a in zip(sp["stage"], sp["key"], sp["aux"])]

    def test_inproc_and_socket_record_the_same_spans(self):
        tb1, tb2 = _tb(), _tb()
        d1 = ControlDaemon(n_instances=1, lease_s=10.0, trace=tb1)
        d2 = ControlDaemon(n_instances=1, lease_s=10.0, trace=tb2)
        server = SocketServer(d2)
        host, port = server.start()
        try:
            sc = SocketClient(host, port)
            s1 = self._play(InProcTransport(d1), tb1)
            s2 = self._play(sc, tb2)
            sc.close()
        finally:
            server.stop()
        assert s1 == s2
        kinds = [s for s, _, _ in s1]
        assert kinds.count("controld.reserve") == 1
        assert kinds.count("controld.send_state") == 2
        assert "controld.tick" not in kinds     # untraced message
        auxes = {(s, a) for s, _, a in s1 if s == "controld.send_state"}
        assert auxes == {("controld.send_state", 1),
                         ("controld.send_state", 0)}  # ok + rejected
        assert all(k == 101 for s, k, _ in s1 if s != "controld.send_state")

    def test_replay_records_nothing_and_digest_is_unchanged(self):
        from repro.controld import Journal
        tb = _tb()
        d = ControlDaemon(n_instances=1, lease_s=10.0, journal=Journal(),
                          trace=tb)
        client = ControldClient(InProcTransport(d))
        client.trace = trace_id(7)
        token = client.reserve(policy="proportional")["token"]
        client.register(token, member_id=0, node_id=0, lane_bits=1)
        n_live = len(tb.spans()["key"])
        assert n_live == 2
        tb2 = _tb()
        d2 = ControlDaemon.recover(d.journal, n_instances=1, lease_s=10.0,
                                   trace=tb2)
        assert len(tb2.spans()["key"]) == 0
        assert d2.state_digest() == d.state_digest()

    def test_simnet_controld_windows_are_traced(self):
        sim = _run("baseline", "host", controld=True)
        sp = sim.trace.spans()
        names = {sim.trace.stage_names[int(s)] for s in sp["stage"]}
        assert any(n.startswith("controld.") for n in names)
        # window trace ids live in the (1 << 62) namespace
        ctl = [int(k) for s, k in zip(sp["stage"], sp["key"])
               if sim.trace.stage_names[int(s)].startswith("controld.")]
        assert ctl and all(k >> 62 == 1 for k in ctl)


class TestMetricsOnFused:
    """Satellite: metrics no longer force the host engine — the fused
    superblock's returned arrays feed the same emission path."""

    MACHINE_STATE = {"process_rss_bytes"}   # real RSS, engine-independent

    def _rows(self, engine: str) -> dict:
        cfg = SimConfig(steps=16, engine=engine, metrics_every=1, **LOOP_KW)
        sim = Simulator(cfg)
        r = sim.run()
        assert r.engine == engine
        return sim.metrics.sample()

    def test_registry_rows_match_host(self):
        h = self._rows("host")
        f = self._rows("fused")
        assert set(h) == set(f)
        for name in sorted(set(h) - self.MACHINE_STATE):
            assert f[name] == pytest.approx(h[name], rel=1e-9, abs=1e-12), \
                name

    def test_exemplars_link_buckets_to_trace_ids(self):
        cfg = SimConfig(steps=16, engine="fused", metrics_every=1,
                        trace=True, **LOOP_KW)
        sim = Simulator(cfg)
        sim.run()
        page = sim.metrics.render()
        assert 'trace_id="' in page
        ex = sim.trace.exemplars(LATENCY_BUCKETS_S)
        assert ex
        for bi, (tid, e2e) in ex.items():
            assert parse_trace_id(tid) >= 0 and e2e > 0

    def test_mix64_matches_fabric_spray(self):
        # the local copy (import-cycle break) must stay the same hash
        from repro.fabric.spray import mix64 as spray_mix64
        xs = np.arange(0, 2**20, 9973, dtype=np.uint64)
        assert np.array_equal(mix64(xs), spray_mix64(xs))


class TestServeTrace:
    def test_rebalance_loop_records_controld_spans(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.models import model as Mo
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg = get_smoke_config("yi_6b")
        params = Mo.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(cfg, ServeConfig(n_replicas=2, lane_bits=1,
                                             max_len=64, rebalance_every=2,
                                             use_controld=True, trace=True),
                            params)
        for _ in range(4):
            eng.submit(np.arange(5), max_new_tokens=3)
        eng.run_until_done(max_ticks=60)
        assert eng.stats["completed"] == 4
        sp = eng.trace.spans()
        names = {eng.trace.stage_names[int(s)] for s in sp["stage"]}
        assert any(n.startswith("controld.") for n in names)
        # setup messages (reserve/register) predate the first stamped
        # window, so every span lives in the window-id namespace
        assert len(sp["key"]) > 0
        assert all(int(k) >> 62 == 1 for k in sp["key"])


class TestFabricSpans:
    """Two-tier fabric: per-LB/per-class aux on lb spans, two-hop VLB
    paths visible as an extra 'fabric' span in the same packet chain."""

    def _sim(self, **kw):
        from repro.fabric import FabricSim, get_fabric_scenario
        sc = get_fabric_scenario("vlb_spray")
        sim = FabricSim(sc.build_config(trace=True, mode="vlb", **kw),
                        scenario=sc)
        r = sim.run()
        assert not r.violations, r.violations
        return sim

    def test_vlb_two_hop_paths_are_distinct_span_trees(self):
        sim = self._sim()
        tb = sim.trace
        sp = tb.spans()
        names = [tb.stage_names[int(s)] for s in sp["stage"]]
        assert "fabric" in names                 # inter-LB hops were taken
        fab = np.asarray([n == "fabric" for n in names])
        lb = np.asarray([n == "lb" for n in names])
        # a fabric hop shares its packet chain with an lb span, and lands
        # on a *different* stacked-calendar instance than the first hop
        two_hop = 0
        for pid in np.unique(sp["pid"][fab]):
            mine = sp["pid"] == pid
            assert (mine & lb).any()
            insts = set(int(a) for a in sp["aux"][mine & (fab | lb)])
            two_hop += len(insts) > 1
        assert two_hop > 0
        # lb aux is the stacked instance id: lb*2 + class < k_lbs*2
        k = sim.cfg.k_lbs
        assert all(0 <= int(a) < 2 * k for a in sp["aux"][lb])

    def test_fabric_reconciles_and_exports(self):
        tb = self._sim().trace
        d = stage_decomposition(tb, 99.0)
        assert d is not None and d["reconcile_rel_err"] < 0.01
        doc = json.loads(tb.to_perfetto_json())
        assert len(doc["traceEvents"]) > 0
