"""repro.simnet.fused: the device-resident closed loop vs the host oracle.

Three invariant families (DESIGN.md §Fused closed loop):

* **Parity** — on every scenario the fused engine supports, it must produce
  the *same simulation* as the per-window host loop: exact counters, the
  same per-bundle latency distribution (fp tolerance), the same weight
  trajectory and audit results. The host loop is the oracle; the fused
  engine is just a faster evaluation order.
* **Superblock split** — cross-window state is carried by ``lax.scan`` and
  across superblocks by the donated carry, so how the run is chopped into
  superblocks (K=1 vs K=8) must be unobservable: identical final state
  digests and identical reports.
* **Jit discipline** — one trace for a family of same-shape configs, one
  jitted dispatch per superblock. Host dispatch cost is the thing this
  engine exists to amortize; a silent retrace would give it back.
"""
import dataclasses

import pytest

from repro.testing.hypo import given, settings, st

from repro.simnet import Simulator, get_scenario
from repro.simnet import fused
from repro.simnet.fused import FusedEngine, fused_supported, unsupported_reason
from repro.simnet.links import LinkConfig
from repro.simnet.sim import SimConfig

EXACT_COUNTERS = [
    "packets_sent", "packets_delivered", "packets_lost_wan",
    "packets_lost_downlink", "packets_dropped_queue",
    "packets_discarded_invalid", "duplicates_absorbed",
    "bundles_sent", "bundles_completed", "bundles_pending",
    "bundles_timed_out", "bundles_vanished", "epoch_switches",
]
CLOSE_FIELDS = [
    "latency_p50_s", "latency_p99_s", "latency_max_s", "latency_mean_s",
]


def _run(name: str, engine: str, steps: int = 60):
    scn = get_scenario(name)
    cfg = scn.build_config(steps=steps, engine=engine)
    return Simulator(cfg, dataclasses.replace(scn)).run()


class TestHostParity:
    @pytest.mark.parametrize("scenario",
                             ["baseline", "straggler", "correlated_loss"])
    def test_fused_matches_host(self, scenario):
        rh = _run(scenario, "host")
        rf = _run(scenario, "fused")
        assert rh.engine == "host" and rf.engine == "fused"
        for f in EXACT_COUNTERS:
            assert getattr(rf, f) == getattr(rh, f), f
        for f in CLOSE_FIELDS:
            assert getattr(rf, f) == pytest.approx(
                getattr(rh, f), rel=1e-9, abs=1e-12), f
        assert rf.per_member_segments == rh.per_member_segments
        assert set(rf.final_weights) == set(rh.final_weights)
        for m, w in rh.final_weights.items():
            assert rf.final_weights[m] == pytest.approx(w, abs=1e-6), m
        assert not rh.violations and not rf.violations
        # the whole closed-loop trajectory, not just the endpoint: every
        # reweight window's weights (rounded in the report) must agree
        assert len(rf.weight_trajectory) == len(rh.weight_trajectory)
        for (sh, wh), (sf, wf) in zip(rh.weight_trajectory,
                                      rf.weight_trajectory):
            assert sh == sf
            assert set(wh) == set(wf)
            for m in wh:
                assert wf[m] == pytest.approx(wh[m], abs=1e-3), (sh, m)

    def test_frozen_weights_parity(self):
        cfg = SimConfig(steps=40, frozen_weights=True)
        rh = Simulator(dataclasses.replace(cfg, engine="host")).run()
        rf = Simulator(dataclasses.replace(cfg, engine="fused")).run()
        assert rf.epoch_switches == rh.epoch_switches == 0
        for f in EXACT_COUNTERS:
            assert getattr(rf, f) == getattr(rh, f), f

    def test_fill_trace_parity(self):
        rh = _run("baseline", "host", steps=30)
        rf = _run("baseline", "fused", steps=30)
        assert len(rf.queue_fill_trace) == len(rh.queue_fill_trace)
        for (th, fh), (tf, ff) in zip(rh.queue_fill_trace,
                                      rf.queue_fill_trace):
            assert tf == pytest.approx(th, rel=1e-9)
            assert ff == pytest.approx(fh, abs=1e-3)


class TestEngineSelection:
    def test_unsupported_configs_fall_back_to_host(self):
        # controld mode runs the daemon protocol per window -> host
        cfg = SimConfig(steps=6, controld=True)
        assert unsupported_reason(cfg) is not None
        r = Simulator(cfg).run()
        assert r.engine == "host"

    def test_hook_scenarios_fall_back_to_host(self):
        for name in ("burst", "link_flap", "lease_churn"):
            scn = get_scenario(name)
            cfg = scn.build_config(steps=6)
            assert not fused_supported(cfg, scn), name
        scn = get_scenario("burst")
        r = Simulator(scn.build_config(steps=6),
                      dataclasses.replace(scn)).run()
        assert r.engine == "host"

    def test_supported_scenarios_use_fused_by_default(self):
        for name in ("baseline", "straggler", "hetero_farm",
                     "correlated_loss"):
            scn = get_scenario(name)
            cfg = scn.build_config(steps=6)
            assert fused_supported(cfg, scn), (
                name, unsupported_reason(cfg, scn))

    def test_unknown_engine_raises(self):
        with pytest.raises(ValueError, match="unknown engine"):
            Simulator(SimConfig(steps=2, engine="gpu")).run()


class TestSuperblockSplit:
    """Chopping a run into superblocks must be unobservable: K=1 (one
    dispatch per window) and K=8 (one per eight) share the same scan-carried
    state, so final digests and reports are identical bit-for-bit."""

    def _digest_and_report(self, cfg: SimConfig, k: int):
        eng = FusedEngine(Simulator(cfg), superblock=k)
        report = eng.run()
        return eng.state_digest(), report

    @settings(max_examples=6)
    @given(seed=st.integers(0, 2**16), steps=st.sampled_from([8, 16, 19]))
    def test_k1_equals_k8(self, seed, steps):
        cfg = SimConfig(steps=steps, seed=seed, engine="fused")
        d1, r1 = self._digest_and_report(cfg, 1)
        d8, r8 = self._digest_and_report(cfg, 8)
        assert d1 == d8
        for f in EXACT_COUNTERS:
            assert getattr(r1, f) == getattr(r8, f), f
        assert r1.latency_p99_s == r8.latency_p99_s
        assert r1.final_weights == r8.final_weights
        assert r1.weight_trajectory == r8.weight_trajectory


class TestJitDiscipline:
    def test_one_trace_one_dispatch_per_superblock(self):
        # a distinctive shape (n_members=5, triggers=3) so this test owns
        # its compile-cache entry even mid-suite
        base = SimConfig(steps=16, n_members=5, triggers_per_step=3,
                         engine="fused")
        cfgs = [
            base,
            dataclasses.replace(base, member_link=LinkConfig(
                rate_Bps=25e6, prop_delay_s=1e-4, jitter_s=2e-5)),
            dataclasses.replace(base, service_per_packet_s=8e-5),
            dataclasses.replace(base, frozen_weights=True),
        ]
        calls0, traces0 = fused.FUSED_STEP_CALLS, fused.FUSED_TRACES
        for cfg in cfgs:
            r = Simulator(cfg).run()
            assert r.engine == "fused"
        assert fused.FUSED_TRACES - traces0 == 1, \
            "heterogeneous same-shape configs must share one trace"
        # 16 windows / 8-window superblock = 2 dispatches per run
        assert fused.FUSED_STEP_CALLS - calls0 == 2 * len(cfgs)

    def test_host_loop_never_touches_fused_counters(self):
        calls0 = fused.FUSED_STEP_CALLS
        Simulator(SimConfig(steps=4, engine="host")).run()
        assert fused.FUSED_STEP_CALLS == calls0
