"""controld: session lifecycle, transports, journal replay, PID properties,
vectorized-policy parity, batched heartbeats, WAL compaction."""
import dataclasses
import os

import numpy as np
import pytest

from repro.controld import (ControlDaemon, ControldClient, ControldError,
                            InProcTransport, Journal, SocketClient,
                            SocketServer)
from repro.controld import messages as M
from repro.controld.policy import (PIDFillPolicy, PolicyConfig,
                                   ProportionalPolicy, make_policy)
from repro.core import route, split64
from repro.core.control_plane import MemberTelemetry, TelemetryArray
from repro.testing.hypo import given, settings, st


@dataclasses.dataclass
class _T:  # telemetry duck-type (MemberTelemetry fields)
    fill: float = 0.0
    rate: float = 1.0
    healthy: bool = True


def _daemon(**kw):
    kw.setdefault("n_instances", 2)
    kw.setdefault("lease_s", 10.0)
    kw.setdefault("epoch_horizon", 256)
    t = 0.0

    def clock():
        return t
    d = ControlDaemon(clock=kw.pop("clock", clock), **kw)
    return d


def _client(daemon):
    return ControldClient(InProcTransport(daemon))


class _ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestLifecycle:
    def test_reserve_register_heartbeat_free(self):
        d = _daemon(journal=Journal())
        c = _client(d)
        r = c.reserve(policy="proportional")
        assert r["instance"] == 0 and r["policy"] == "proportional"
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
        c.tick(current_event=0)
        assert d.sessions[r["token"]].started
        for m in range(4):
            out = c.send_state(r["token"], m, fill=0.5)
            assert out["lease_expires"] > 0
        freed = c.free(r["token"])
        assert freed["instance"] == 0
        assert d._free_instances == [0, 1]

    def test_token_scopes_all_member_calls(self):
        d = _daemon()
        c = _client(d)
        r = c.reserve()
        with pytest.raises(ControldError):
            c.register("r999999", member_id=0)
        with pytest.raises(ControldError):
            c.send_state("bogus", 0, fill=0.1)
        # a second tenant's token cannot touch the first tenant's members
        r2 = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        with pytest.raises(ControldError):
            c.send_state(r2["token"], 0, fill=0.1)

    def test_reservation_exhaustion_and_hint(self):
        d = _daemon(n_instances=2)
        c = _client(d)
        r1 = c.reserve(instance_hint=1)
        assert r1["instance"] == 1
        c.reserve()
        with pytest.raises(ControldError):
            c.reserve()
        c.free(r1["token"])
        assert c.reserve()["instance"] == 1

    def test_unknown_policy_is_rejected_and_instance_returned(self):
        d = _daemon(n_instances=1)
        c = _client(d)
        with pytest.raises(ControldError):
            c.reserve(policy="nonsense")
        assert c.reserve()["instance"] == 0  # instance was not leaked

    def test_bad_policy_param_rejected_without_poisoning_the_journal(self):
        """A non-numeric policy param (valid JSON!) must come back as a
        protocol rejection — not a TypeError that leaks the instance and,
        being journaled pre-execution, crashes every future recover()."""
        d = _daemon(n_instances=1, journal=Journal())
        c = _client(d)
        with pytest.raises(ControldError):
            c.reserve(policy="pid", policy_params={"kp": None})
        assert c.reserve()["instance"] == 0  # not leaked
        rec = ControlDaemon.recover(d.journal, n_instances=1,
                                    lease_s=10.0, epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_bad_register_fields_rejected_without_poisoning_the_journal(self):
        """weight=0/nan/inf and bad lane_bits are protocol-valid JSON that
        used to crash the *starting Tick* (after its WAL append) — they must
        be rejected at Register time and the journal must stay replayable."""
        d = _daemon(n_instances=1, journal=Journal())
        c = _client(d)
        r = c.reserve()
        for bad in (dict(weight=0.0), dict(weight=float("nan")),
                    dict(weight=float("inf")), dict(weight=-1.0),
                    dict(lane_bits=99)):
            with pytest.raises(ControldError):
                c.register(r["token"], member_id=0, node_id=0, **bad)
        c.register(r["token"], member_id=0, node_id=0)  # a good one
        c.tick(current_event=0)
        assert d.sessions[r["token"]].started
        rec = ControlDaemon.recover(d.journal, n_instances=1,
                                    lease_s=10.0, epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_deregister_drains_from_next_epoch(self):
        clk = _ManualClock()
        d = _daemon(clock=clk)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        c.deregister(r["token"], member_id=1)
        c.tick(current_event=500)  # membership delta -> epoch switch
        s = d.sessions[r["token"]]
        evs = np.arange(2000, 2512, dtype=np.uint64)
        hi, lo = split64(evs)
        routed = route(s.manager.device_tables(), hi, lo,
                       np.zeros(len(evs), np.uint32))
        assert 1 not in set(np.asarray(routed.member).tolist())


class TestLeases:
    def test_lease_expiry_drains_like_mark_failed(self):
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        # members 0 and 2 heartbeat; member 1 goes silent
        clk.t = 4.0
        c.send_state(r["token"], 0, fill=0.5)
        c.send_state(r["token"], 2, fill=0.5)
        clk.t = 6.0  # member 1's lease (granted at t=0) lapses
        tick = c.tick(current_event=1000)
        assert tick["sessions"][r["token"]]["expired"] == [1]
        s = d.sessions[r["token"]]
        assert 1 not in s.cp.members
        # heartbeats for a lapsed lease are rejected: re-register to rejoin
        with pytest.raises(ControldError):
            c.send_state(r["token"], 1, fill=0.1)
        c.register(r["token"], member_id=1, node_id=1)
        c.send_state(r["token"], 1, fill=0.1)
        c.tick(current_event=2000)
        assert 1 in s.cp.members

    def test_expiry_drain_is_hitless_for_inflight_epoch(self):
        """Satellite: a lease lapsing between schedule_epoch and the boundary
        must not disturb the in-flight epoch — old events keep routing to
        the lapsed member; only the post-boundary epoch excludes it."""
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0, epoch_horizon=400)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        s = d.sessions[r["token"]]
        # drive one reweight so an epoch boundary is scheduled ahead
        clk.t = 1.0
        for m in range(3):
            c.send_state(r["token"], m, fill=0.9 if m == 2 else 0.2)
        c.tick(current_event=100)  # schedules a boundary at ~500
        boundary = s.manager.records[s.manager.current_epoch].start_event
        assert boundary > 100
        # members 0/1 keep heart-beating; member 2 goes silent and its lease
        # lapses while that epoch is still in flight
        clk.t = 4.0
        c.send_state(r["token"], 0, fill=0.2)
        c.send_state(r["token"], 1, fill=0.2)
        clk.t = 6.5
        tick = c.tick(current_event=200)  # hysteresis: boundary still ahead
        assert tick["sessions"][r["token"]]["expired"] == [2]
        assert 2 not in s.cp.members
        # in-flight events (pre-boundary epochs) still route to member 2
        evs = np.arange(0, boundary, dtype=np.uint64)
        hi, lo = split64(evs)
        routed = route(s.manager.device_tables(), hi, lo,
                       np.zeros(len(evs), np.uint32))
        assert 2 in set(np.asarray(routed.member).tolist())
        # once traffic crosses the boundary, the next tick drains it
        c.tick(current_event=boundary + 10)
        evs2 = np.arange(boundary + 600, boundary + 1112, dtype=np.uint64)
        hi2, lo2 = split64(evs2)
        routed2 = route(s.manager.device_tables(), hi2, lo2,
                        np.zeros(len(evs2), np.uint32))
        assert 2 not in set(np.asarray(routed2.member).tolist())

    def test_late_heartbeat_rejected_even_before_a_tick_reaps(self):
        """The lease rule is independent of tick cadence: a heartbeat after
        the expiry instant is rejected even while the lease is still
        awaiting reaping by the next Tick."""
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0)
        c = _client(d)
        r = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        clk.t = 6.0  # lapsed at t=5; no tick has run since
        with pytest.raises(ControldError):
            c.send_state(r["token"], 0, fill=0.3)
        tick = c.tick(current_event=100)
        assert tick["sessions"][r["token"]]["expired"] == [0]

    def test_all_leases_expired_keeps_last_epoch_live(self):
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=2.0)
        c = _client(d)
        r = c.reserve()
        for m in range(2):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        clk.t = 10.0
        tick = c.tick(current_event=100)
        assert tick["sessions"][r["token"]]["expired"] == [0, 1]
        s = d.sessions[r["token"]]
        assert s.manager.current_epoch is not None  # no teardown


class TestTransportParity:
    SCRIPT = [
        M.Reserve(policy="pid", instance_hint=1),
        M.Register(token="r000000", member_id=0, node_id=0, lane_bits=1),
        M.Register(token="r000000", member_id=1, node_id=1),
        M.Tick(current_event=0),
        M.SendState(token="r000000", member_id=0, fill=0.8),
        M.SendState(token="r000000", member_id=1, fill=0.2),
        M.SendState(token="bogus", member_id=1, fill=0.2),  # rejection too
        # a batch window, including a per-member rejection (member 9 holds
        # no lease) — socket and in-proc must agree on the whole reply
        M.SendStateBatch(token="r000000", member_ids=(0, 1, 9),
                         fills=(0.7, 0.3, 0.5), rates=(1.0, 1.0, 1.0),
                         healthy=(True, True, True)),
        M.Tick(current_event=600),
        M.Deregister(token="r000000", member_id=1),
        M.Tick(current_event=1200),
        M.Status(),
    ]

    def _play(self, transport, daemon):
        clk_out = []
        replies = []
        for msg in self.SCRIPT:
            r = transport.call(msg)
            replies.append((r.ok, r.error, r.data))
            clk_out.append(daemon.state_digest())
        return replies, clk_out

    def test_inproc_and_socket_property_equal(self):
        """The same message script through both transports produces
        identical replies AND identical daemon state at every step."""
        clk1, clk2 = _ManualClock(), _ManualClock()
        d1 = _daemon(clock=clk1)
        d2 = _daemon(clock=clk2)
        server = SocketServer(d2)
        host, port = server.start()
        try:
            sc = SocketClient(host, port)
            r1, s1 = self._play(InProcTransport(d1), d1)
            r2, s2 = self._play(sc, d2)
            sc.close()
        finally:
            server.stop()
        assert s1 == s2
        for (ok1, err1, data1), (ok2, err2, data2) in zip(r1, r2):
            assert (ok1, err1) == (ok2, err2)
            assert data1 == data2


class TestJournalReplay:
    def _workload(self, d):
        clk = d.clock
        c = _client(d)
        r = c.reserve(policy="pid", policy_params={"kd": 0.1})
        r2 = c.reserve(policy="proportional")
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
        c.register(r2["token"], member_id=0, node_id=10)
        c.tick(current_event=0)
        ev = 0
        for k in range(6):
            clk.t += 1.0
            for m in range(4):
                c.send_state(r["token"], m, fill=0.9 if m == 0 else 0.3)
            if k < 2:  # r2's member stops heart-beating after round 2
                c.send_state(r2["token"], 0, fill=0.4)
            ev += 400
            c.tick(current_event=ev)
        c.deregister(r["token"], member_id=3)
        clk.t = 11.0  # past r2's lease (renewed at t=2), within r's (t=6)
        c.tick(current_event=ev + 400)
        return d

    def test_replay_reproduces_byte_identical_state(self):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        recovered = ControlDaemon.recover(d.journal, n_instances=2,
                                          lease_s=8.0, epoch_horizon=256)
        assert recovered.state_digest() == d.state_digest()
        # calendars specifically must be byte-identical
        for token, s in d.sessions.items():
            s2 = recovered.sessions[token]
            assert set(s.manager.state.calendars) == set(s2.manager.state.calendars)
            for eid, cal in s.manager.state.calendars.items():
                assert cal.tobytes() == s2.manager.state.calendars[eid].tobytes()

    def test_recovered_daemon_keeps_working_and_journaling(self):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        seq = d.journal.seq
        rec = ControlDaemon.recover(d.journal, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256, clock=clk)
        c = ControldClient(InProcTransport(rec))
        token = sorted(rec.sessions)[0]
        c.send_state(token, 0, fill=0.5)
        assert rec.journal.seq == seq + 1  # seq-contiguous after recovery
        # ...and the twice-recovered daemon still matches
        rec2 = ControlDaemon.recover(rec.journal, n_instances=2, lease_s=8.0,
                                     epoch_horizon=256)
        assert rec2.state_digest() == rec.state_digest()

    def test_jsonl_roundtrip_and_torn_tail(self, tmp_path):
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        digest = d.state_digest()
        with open(path, "a") as f:
            f.write('{"seq": 9999, "kind": "tick", "payl')  # torn append
        loaded = Journal.load(path)
        assert loaded.seq == d.journal.seq  # torn line dropped
        rec = ControlDaemon.recover(loaded, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == digest

    def test_recover_from_file_keeps_persisting_without_duplication(
            self, tmp_path):
        """Recovering from an on-disk journal continues appending to the
        same file seq-contiguously — a second recovery sees ONE history,
        never a duplicated prefix (the --serve restart path)."""
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        d.journal.close()
        rec = ControlDaemon.recover(Journal.load(path), n_instances=2,
                                    lease_s=8.0, epoch_horizon=256, clock=clk)
        assert rec.state_digest() == d.state_digest()
        token = sorted(rec.sessions)[0]
        ControldClient(InProcTransport(rec)).send_state(token, 0, fill=0.5)
        rec.journal.close()
        reloaded = Journal.load(path)
        seqs = [e.seq for e in reloaded.entries]
        assert seqs == list(range(len(seqs)))  # one contiguous history
        rec2 = ControlDaemon.recover(reloaded, n_instances=2, lease_s=8.0,
                                     epoch_horizon=256)
        assert rec2.state_digest() == rec.state_digest()

    def test_snapshot_restore(self, tmp_path):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        snap_dir = str(tmp_path / "snaps")
        d.journal.snapshot(snap_dir)
        j = Journal.restore(snap_dir)
        rec = ControlDaemon.recover(j, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_file_backed_journal_memory_stays_bounded(self, tmp_path):
        """A journal mirrored to disk must not also retain every heartbeat
        in RAM (a --serve daemon journals forever); the file is the replay
        source, and snapshot() reads it from there."""
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        assert d.journal.entries == []          # disk-only retention
        assert d.journal.seq > 0
        snap_dir = str(tmp_path / "snaps")
        d.journal.snapshot(snap_dir)            # snapshots from the file
        rec = ControlDaemon.recover(Journal.restore(snap_dir),
                                    n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()


class TestPIDProperties:
    """Hypothesis properties for the PID fill policy (satellite task)."""

    @settings(max_examples=40)
    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=6),
           st.integers(min_value=1, max_value=30))
    def test_weights_always_normalized_and_nonnegative(self, fills, steps):
        pol = PIDFillPolicy(PolicyConfig(kd=0.2))
        n = len(fills)
        pol.reset(range(n))
        w = {m: 1.0 for m in range(n)}
        for _ in range(steps):
            w = pol.update(w, {m: _T(fill=fills[m]) for m in range(n)})
            for v in w.values():
                assert v >= 0.0
            live = [v for v in w.values() if v > 0]
            assert live, "policy drove every member to zero"
            for v in live:
                assert pol.cfg.min_weight <= v <= pol.cfg.max_weight

    @settings(max_examples=25)
    @given(st.floats(min_value=0.6, max_value=1.0),
           st.integers(min_value=50, max_value=400))
    def test_anti_windup_bounds_integral_under_saturation(self, fill, steps):
        # huge integral_limit: only back-calculation can bound the windup
        cfg = PolicyConfig(kd=0.0, integral_limit=100.0, output_limit=0.5)
        pol = PIDFillPolicy(cfg)
        pol.reset(range(2))
        w = {0: 1.0, 1: 1.0}
        err = cfg.target_fill - fill  # sustained negative error
        for _ in range(steps):
            w = pol.update(w, {0: _T(fill=fill), 1: _T(fill=cfg.target_fill)})
        bound = cfg.output_limit + cfg.kp * abs(err) + 1e-9
        assert abs(pol._integral[0]) <= bound
        # without anti-windup the clip would be the only bound (=100)
        assert abs(pol._integral[0]) < cfg.integral_limit

    @settings(max_examples=25)
    @given(st.lists(st.floats(min_value=0.1, max_value=4.0),
                    min_size=2, max_size=6),
           st.integers(min_value=1, max_value=10))
    def test_zero_error_reproduces_proportional_fixed_point(self, w0, steps):
        """At setpoint fill, PID and proportional converge to the same
        fixed point (the normalized clip of the weights) — the PID is a
        strict generalization, not a different equilibrium."""
        cfg = PolicyConfig(kd=0.3)
        pid, prop = PIDFillPolicy(cfg), ProportionalPolicy(cfg)
        n = len(w0)
        pid.reset(range(n))
        prop.reset(range(n))
        w1 = {m: w0[m] for m in range(n)}
        w2 = {m: w0[m] for m in range(n)}
        tele = {m: _T(fill=cfg.target_fill) for m in range(n)}
        for _ in range(steps):
            w1 = pid.update(w1, tele)
            w2 = prop.update(w2, tele)
        assert w1 == w2
        # and it IS a fixed point: one more step changes nothing (up to the
        # renormalization's float round-trip, mean(w)/1.0 == 1 ± 1 ulp)
        w_next = pid.update(dict(w1), tele)
        assert w_next == pytest.approx(w1, rel=1e-12)

    def test_unhealthy_member_goes_to_zero_both_policies(self):
        for pol in (PIDFillPolicy(), ProportionalPolicy()):
            pol.reset(range(3))
            w = pol.update({0: 1.0, 1: 1.0, 2: 1.0},
                           {0: _T(fill=0.5), 1: _T(fill=0.5, healthy=False),
                            2: _T(fill=0.5)})
            assert w[1] == 0.0 and w[0] > 0 and w[2] > 0

    def test_make_policy_rejects_unknown_params(self):
        with pytest.raises(ValueError):
            make_policy("pid", {"kq": 1.0})
        with pytest.raises(ValueError):
            make_policy("banana")


class TestVectorPolicyParity:
    """Satellite: the [M]-lane ``update_lanes`` path must be property-equal
    to the scalar dict policies element-wise — including stale/missing
    members, drains and saturation/anti-windup edges. The np engine is
    required to match *bitwise*; the jnp engine (float32 on device) within
    float tolerance."""

    def _run_both(self, pol_cls, kd, fills, healthy, present, steps,
                  engine="np", cfg=None):
        cfg = cfg or PolicyConfig(kd=kd)
        scalar, lanes = pol_cls(cfg), pol_cls(cfg)
        n = len(fills)
        scalar.reset(range(n))
        lanes.reset(range(n))
        w_s = {m: 1.0 + 0.25 * m for m in range(n)}
        w_l = np.asarray([w_s[m] for m in range(n)], np.float64)
        ids = np.arange(n)
        for k in range(steps):
            # rotate the pattern so every member cycles through
            # present/missing/unhealthy states across steps
            f = np.roll(np.asarray(fills, np.float64), k)
            h = np.roll(np.asarray(healthy, bool), k)
            pr = np.roll(np.asarray(present, bool), k)
            tele = {m: MemberTelemetry(fill=float(f[m]), healthy=bool(h[m]))
                    for m in range(n) if pr[m]}
            w_s = scalar.update(w_s, tele)
            w_l = lanes.update_lanes(ids, w_l, f, h, present=pr,
                                     engine=engine)
        return scalar, lanes, w_s, w_l

    @settings(max_examples=30)
    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=8),
           st.lists(st.booleans(), min_size=2, max_size=8),
           st.lists(st.booleans(), min_size=2, max_size=8),
           st.integers(min_value=1, max_value=12))
    def test_np_engine_matches_scalar_bitwise(self, fills, healthy, present,
                                              steps):
        n = len(fills)
        healthy = (healthy * n)[:n]
        present = (present * n)[:n]
        for pol_cls in (ProportionalPolicy, PIDFillPolicy):
            scalar, lanes, w_s, w_l = self._run_both(
                pol_cls, 0.3, fills, healthy, present, steps)
            assert w_s == {m: float(w_l[m]) for m in range(n)}
            assert scalar.state() == lanes.state()

    @settings(max_examples=10)
    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=8),
           st.integers(min_value=1, max_value=8))
    def test_jnp_engine_matches_scalar_within_float32(self, fills, steps):
        n = len(fills)
        ones = [True] * n
        for pol_cls in (ProportionalPolicy, PIDFillPolicy):
            _, _, w_s, w_l = self._run_both(pol_cls, 0.2, fills, ones, ones,
                                            steps, engine="jnp")
            ref = np.asarray([w_s[m] for m in range(n)])
            np.testing.assert_allclose(w_l, ref, rtol=1e-4, atol=1e-5)

    @settings(max_examples=10)
    @given(st.floats(min_value=0.6, max_value=1.0),
           st.integers(min_value=50, max_value=200))
    def test_anti_windup_parity_under_saturation(self, fill, steps):
        """Sustained saturation with a huge integral_limit: only
        back-calculation bounds the windup — and the lanes path must land
        on the exact same integral as the scalar oracle."""
        cfg = PolicyConfig(kd=0.0, integral_limit=100.0, output_limit=0.5)
        fills = [fill, cfg.target_fill]
        ones = [True, True]
        scalar, lanes, w_s, w_l = self._run_both(
            PIDFillPolicy, 0.0, fills, ones, ones, steps, cfg=cfg)
        assert scalar._integral == lanes._integral
        bound = cfg.output_limit + cfg.kp * abs(cfg.target_fill - fill) + 1e-9
        assert abs(lanes._integral[0]) <= bound

    def test_update_weights_accepts_telemetry_array(self):
        """core satellite: ``update_weights``/``feedback`` take the array
        snapshot and produce the same weights as the dict path."""
        from repro.core.control_plane import LoadBalancerControlPlane
        from repro.core.epoch import EpochManager
        from repro.core.tables import MemberSpec

        cps = []
        for _ in range(2):
            cp = LoadBalancerControlPlane(EpochManager(max_members=64))
            cp.start({m: MemberSpec(node_id=m, lane_bits=1)
                      for m in range(4)})
            cps.append(cp)
        tele = {0: MemberTelemetry(fill=0.9), 1: MemberTelemetry(fill=0.1),
                2: MemberTelemetry(fill=0.5, healthy=False)}  # 3 missing
        w_dict = cps[0].update_weights(tele)
        arr = TelemetryArray.from_dict(tele, member_ids=range(4))
        w_arr = cps[1].update_weights(arr)
        assert w_dict == w_arr
        # and align() re-lanes a differently-ordered snapshot identically
        shuffled = TelemetryArray.from_dict(tele, member_ids=[2, 0, 1])
        w3 = shuffled.align(np.arange(4))
        assert w3.present.tolist() == [True, True, True, False]
        assert w3.fill.tolist()[:3] == [0.9, 0.1, 0.5]


class TestSendStateBatch:
    def _daemon(self, **kw):
        clk = _ManualClock()
        kw.setdefault("n_instances", 1)
        kw.setdefault("lease_s", 10.0)
        d = ControlDaemon(clock=kw.pop("clock", clk), **kw)
        d._test_clock = clk
        return d

    def test_batch_digest_equals_m_scalar_sends(self):
        """One SendStateBatch must leave the daemon in the byte-identical
        state of M SendState messages at the same instant."""
        daemons = [self._daemon(), self._daemon()]
        clients = [_client(d) for d in daemons]
        toks = []
        for c in clients:
            r = c.reserve(policy="pid")
            for m in range(5):
                c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
            c.tick(current_event=0)
            toks.append(r["token"])
        fills = [0.9, 0.2, 0.4, 0.6, 0.1]
        clients[0].send_state_batch(toks[0], range(5), fills)
        for m in range(5):
            clients[1].send_state(toks[1], m, fill=fills[m])
        clients[0].tick(current_event=600)
        clients[1].tick(current_event=600)
        assert daemons[0].state_digest() == daemons[1].state_digest()

    def test_partial_rejection_and_lease_renewal(self):
        d = self._daemon(lease_s=5.0)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        d._test_clock.t = 4.0
        c.send_state(r["token"], 0, fill=0.1)
        c.send_state(r["token"], 1, fill=0.1)
        d._test_clock.t = 6.0  # member 2's lease (t=0 grant) lapsed
        reply = c.send_state_batch(r["token"], [0, 1, 2, 7],
                                   [0.5, 0.6, 0.7, 0.8])
        assert reply["n_accepted"] == 2
        assert set(reply["rejected"]) == {"2", "7"}
        assert "lapsed" in reply["rejected"]["2"]
        assert "no lease" in reply["rejected"]["7"]
        # accepted members got renewed to now + lease_s
        s = next(iter(d.sessions.values()))
        assert float(s.lanes.lease_expires[0]) == pytest.approx(11.0)
        # the lapsed member still awaits the Tick reap (protocol unchanged)
        tick = c.tick(current_event=100)
        assert tick["sessions"][r["token"]]["expired"] == [2]

    def test_batch_length_mismatch_rejected_and_replayable(self):
        d = self._daemon(journal=Journal())
        c = _client(d)
        r = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        with pytest.raises(ControldError):
            c._call(M.SendStateBatch(token=r["token"], member_ids=(0, 1),
                                     fills=(0.5,), rates=(1.0, 1.0),
                                     healthy=(True, True)))
        with pytest.raises(ControldError):
            c._call(M.SendStateBatch(token=r["token"], member_ids=(0,),
                                     fills=("nan-ish",), rates=(1.0,),
                                     healthy=(True,)))
        rec = ControlDaemon.recover(d.journal, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()

    def test_non_integer_member_id_is_a_protocol_rejection(self):
        """A string/float member_id is valid JSON: it must come back as a
        clean rejection (not a TypeError after the WAL append — which would
        poison every future recover()), and must not kill the selector
        server's event loop for other clients."""
        d = self._daemon(journal=Journal())
        c = _client(d)
        r = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        for bad in ("5", 1.5, True, None):
            with pytest.raises(ControldError):
                c._call(M.SendState(token=r["token"], member_id=bad,
                                    fill=0.1))
            with pytest.raises(ControldError):
                c._call(M.Deregister(token=r["token"], member_id=bad))
            with pytest.raises(ControldError):
                c._call(M.Register(token=r["token"], member_id=bad))
        rec = ControlDaemon.recover(d.journal, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()

    def test_server_loop_survives_a_poison_connection(self):
        """One connection triggering an unexpected handler exception must
        cost that connection only — the event loop keeps serving others."""
        d = ControlDaemon(n_instances=1, lease_s=1e9)
        server = SocketServer(d)
        host, port = server.start()
        try:
            good = ControldClient(SocketClient(host, port))
            good.reserve()
            bad = SocketClient(host, port)
            original = d.handle
            d.handle = lambda msg, now=None: (_ for _ in ()).throw(
                RuntimeError("injected daemon bug"))
            with pytest.raises(Exception):
                bad.call(M.Status())  # conn torn down, no reply
            d.handle = original
            assert good.status()["free_instances"] == []  # loop still alive
        finally:
            server.stop()

    def test_align_with_empty_snapshot(self):
        empty = TelemetryArray.from_dict({}, member_ids=[])
        out = empty.align(np.arange(3))
        assert out.present.tolist() == [False] * 3
        assert out.fill.tolist() == [0.0] * 3

    def test_batch_non_integer_ids_rejected_per_member(self):
        """Batch ids go through the same _member_index validation as
        SendState: a float/bool/huge-int id is a per-member rejection —
        never an unsafe cast onto another member's lane, and never an
        OverflowError after the WAL append (which would make the journal
        permanently unrecoverable)."""
        d = self._daemon(journal=Journal())
        c = _client(d)
        r = c.reserve()
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        s = next(iter(d.sessions.values()))
        before = s.lanes.lease_expires.copy()
        reply = c.send_state_batch(r["token"], [0, 2.9, True, 10**30],
                                   [0.4, 0.9, 0.9, 0.9])
        assert reply["n_accepted"] == 1
        assert set(reply["rejected"]) == {"2.9", "True", str(10**30)}
        # lanes 1/2/3 untouched: no truncated-id lease renewal or overwrite
        assert (s.lanes.lease_expires[1:4] == before[1:4]).all()
        assert float(s.lanes.fill[2]) == 0.0 and float(s.lanes.fill[1]) == 0.0
        rec = ControlDaemon.recover(d.journal, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()

    def test_duplicate_ids_last_sample_wins(self):
        d = self._daemon()
        c = _client(d)
        r = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        reply = c.send_state_batch(r["token"], [0, 0, 0], [0.1, 0.5, 0.9])
        assert reply["n_accepted"] == 3
        s = next(iter(d.sessions.values()))
        assert float(s.lanes.fill[0]) == 0.9

    def test_cp_restart_with_batched_journal_entries(self):
        """Acceptance: SendStateBatch journal entries replay to a
        byte-identical state digest across a daemon kill/recover."""
        d = self._daemon(n_instances=2, journal=Journal())
        c = _client(d)
        toks = []
        for inst in range(2):
            r = c.reserve(policy="pid" if inst else "proportional")
            for m in range(4):
                c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
            toks.append(r["token"])
        c.tick(current_event=0)
        ev = 0
        for k in range(6):
            d._test_clock.t += 1.0
            for t in toks:
                c.send_state_batch(t, range(4),
                                   [0.2 + 0.1 * ((m + k) % 4)
                                    for m in range(4)])
            ev += 400
            c.tick(current_event=ev)
        rec = ControlDaemon.recover(d.journal, n_instances=2, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()
        for token, s in d.sessions.items():
            s2 = rec.sessions[token]
            for eid, cal in s.manager.state.calendars.items():
                assert cal.tobytes() == s2.manager.state.calendars[eid].tobytes()

    def test_socket_batch_and_pipelining_parity(self):
        """Satellite: SendStateBatch (and a pipelined call_many burst) over
        the socket produce the same replies and daemon state as in-proc."""
        clk1, clk2 = _ManualClock(), _ManualClock()
        d1 = ControlDaemon(n_instances=1, lease_s=10.0, clock=clk1)
        d2 = ControlDaemon(n_instances=1, lease_s=10.0, clock=clk2)
        server = SocketServer(d2)
        host, port = server.start()
        try:
            ct1 = InProcTransport(d1)
            ct2 = SocketClient(host, port)
            script = [M.Reserve(policy="pid")] + [
                M.Register(token="r000000", member_id=m, node_id=m,
                           lane_bits=1) for m in range(6)
            ] + [
                M.Tick(current_event=0),
                M.SendStateBatch(token="r000000",
                                 member_ids=tuple(range(6)),
                                 fills=(0.9, 0.1, 0.3, 0.5, 0.7, 0.2),
                                 rates=(1.0,) * 6, healthy=(True,) * 6),
                M.Tick(current_event=600),
                M.Status(),
            ]
            r1 = ct1.call_many(script)
            r2 = ct2.call_many(script)  # one pipelined burst over the wire
            ct2.close()
        finally:
            server.stop()
        assert d1.state_digest() == d2.state_digest()
        for a, b in zip(r1, r2):
            assert (a.ok, a.error, a.data) == (b.ok, b.error, b.data)


class TestRegisterBatch:
    """RegisterBatch mirrors SendStateBatch: one frame, one journal entry,
    per-member validation rejections in the reply — semantics identical to
    N scalar Registers at the same instant."""

    def _daemon(self, **kw):
        clk = _ManualClock()
        kw.setdefault("n_instances", 1)
        kw.setdefault("lease_s", 10.0)
        d = ControlDaemon(clock=kw.pop("clock", clk), **kw)
        d._test_clock = clk
        return d

    def test_batch_digest_equals_n_scalar_registers(self):
        daemons = [self._daemon(), self._daemon()]
        clients = [_client(d) for d in daemons]
        toks = [c.reserve(policy="pid")["token"] for c in clients]
        weights = [1.0, 2.0, 0.5, 1.5]
        clients[0].register_batch(toks[0], range(4), lane_bits=1,
                                  weights=weights)
        for m in range(4):
            clients[1].register(toks[1], member_id=m, node_id=m,
                                lane_bits=1, weight=weights[m])
        for c, tok in zip(clients, toks):
            c.tick(current_event=0)
            c.send_state_batch(tok, range(4), [0.8, 0.1, 0.4, 0.6])
            c.tick(current_event=600)
        assert daemons[0].state_digest() == daemons[1].state_digest()

    def test_per_member_rejection(self):
        d = self._daemon(max_members=8)
        c = _client(d)
        tok = c.reserve()["token"]
        r = c.register_batch(tok, [0, 1, 99, "x", 2, 3],
                             weights=[1, 1, 1, 1, -5, 1])
        assert r["n_accepted"] == 3
        assert r["member_ids"] == [0, 1, 3]
        assert set(r["rejected"]) == {"99", "x", "2"}
        assert "out of range" in r["rejected"]["99"]
        assert "out of range" in r["rejected"]["x"]
        assert "weight" in r["rejected"]["2"]
        s = next(iter(d.sessions.values()))
        assert s.counters["registered"] == 3
        assert sorted(s.lanes.lease_ids()) == [0, 1, 3]

    def test_one_journal_entry_and_replay(self):
        j = Journal()
        d = self._daemon(journal=j)
        c = _client(d)
        tok = c.reserve()["token"]
        c.register_batch(tok, range(6), lane_bits=1)
        c.tick(current_event=0)
        kinds = [e.kind for e in j.entries]
        assert kinds.count("register_batch") == 1
        assert "register" not in kinds
        rec = ControlDaemon.recover(j, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()

    def test_rejoin_wave_on_live_session(self):
        d = self._daemon(lease_s=5.0)
        c = _client(d)
        tok = c.reserve()["token"]
        c.register_batch(tok, range(3), lane_bits=1)
        c.tick(current_event=0)
        d._test_clock.t = 6.0  # everyone's lease lapsed
        c.tick(current_event=10)
        r = c.register_batch(tok, range(3), lane_bits=1)
        assert r["n_accepted"] == 3 and not r["rejected"]
        c.tick(current_event=20)
        s = next(iter(d.sessions.values()))
        assert sorted(s.cp.members) == [0, 1, 2]
        assert s.counters["leases_expired"] == 3

    def test_length_mismatch_is_a_protocol_rejection(self):
        j = Journal()
        d = self._daemon(journal=j)
        c = _client(d)
        tok = c.reserve()["token"]
        with pytest.raises(ControldError):
            c._call(M.RegisterBatch(token=tok, member_ids=(0, 1),
                                    node_ids=(0,), base_lanes=(0, 0),
                                    lane_bits=(1, 1), weights=(1.0, 1.0)))
        rec = ControlDaemon.recover(j, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()


class TestJournalCompaction:
    def _workload(self, d, rounds=8):
        clk = d.clock
        c = _client(d)
        r = c.reserve(policy="pid")
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
        c.tick(current_event=0)
        ev = 0
        for k in range(rounds):
            clk.t += 1.0
            c.send_state_batch(r["token"], range(4),
                               [0.3 + 0.05 * ((m + k) % 4)
                                for m in range(4)])
            ev += 400
            c.tick(current_event=ev)
        return d

    def test_compaction_bounds_wal_and_recovers_identically(self, tmp_path):
        """Satellite: the WAL rolls into snapshots every N entries; the live
        file stays bounded and recovery from snapshot+tail is
        digest-identical."""
        path = os.path.join(tmp_path, "journal.jsonl")
        snap_dir = os.path.join(tmp_path, "snaps")
        clk = _ManualClock()
        d = self._workload(ControlDaemon(
            n_instances=1, lease_s=100.0, clock=clk,
            journal=Journal(path, snapshot_dir=snap_dir, compact_every=5)))
        digest = d.state_digest()
        total = d.journal.seq + 1
        with open(path) as f:
            tail_lines = len([ln for ln in f.read().splitlines() if ln])
        assert tail_lines < 5 <= total  # the WAL never exceeds N entries
        assert Journal.latest_snapshot(snap_dir) is not None
        history = Journal.restore(snap_dir, tail_path=path)
        assert history.seq == d.journal.seq
        rec = ControlDaemon.recover(history, n_instances=1, lease_s=100.0)
        assert rec.state_digest() == digest

    def test_resumed_daemon_stays_seq_contiguous_and_compacting(
            self, tmp_path):
        path = os.path.join(tmp_path, "journal.jsonl")
        snap_dir = os.path.join(tmp_path, "snaps")
        clk = _ManualClock()
        d = self._workload(ControlDaemon(
            n_instances=1, lease_s=100.0, clock=clk,
            journal=Journal(path, snapshot_dir=snap_dir, compact_every=5)))
        d.journal.close()
        seq0 = d.journal.seq
        history = Journal.restore(snap_dir, tail_path=path)
        rec = ControlDaemon.recover(
            history, n_instances=1, lease_s=100.0, clock=clk,
            live_journal=Journal.resume(path, history.seq,
                                        snapshot_dir=snap_dir,
                                        compact_every=5))
        assert rec.state_digest() == d.state_digest()
        c = _client(rec)
        token = sorted(rec.sessions)[0]
        for k in range(12):  # crosses at least one more compaction
            clk.t += 1.0
            c.send_state_batch(token, range(4), [0.4] * 4)
        assert rec.journal.seq == seq0 + 12
        # a second full recovery still sees ONE contiguous history
        rec.journal.close()
        history2 = Journal.restore(snap_dir, tail_path=path)
        assert [e.seq for e in history2.entries] == list(
            range(history2.seq + 1))
        rec2 = ControlDaemon.recover(history2, n_instances=1, lease_s=100.0)
        assert rec2.state_digest() == rec.state_digest()


class TestTrainerControldClient:
    def test_trainer_ingest_via_daemon_session(self):
        """Satellite: launch/train DP workers register as leased members on
        a daemon session instead of the embedded CP (like serve/simnet)."""
        import jax

        from repro.configs import get_smoke_config
        from repro.train import optimizer as OPT
        from repro.train import train_step as TS
        from repro.train.trainer import Trainer, TrainerConfig

        cfg = get_smoke_config("yi_6b")
        tcfg = TS.TrainConfig(adamw=OPT.AdamWConfig(lr=1e-3, decay_steps=10),
                              remat=False, lb_ingest=False,
                              q_chunk=16, k_chunk=16)
        tr = Trainer(cfg, tcfg,
                     TrainerConfig(n_members=4, recalendar_every=2,
                                   ckpt_every=1000,
                                   ckpt_dir="/tmp/repro_controld_train",
                                   use_controld=True))
        tr.init_or_restore(jax.random.PRNGKey(0))
        hist = tr.run(4, batch=2, seq=16)
        assert len(hist) == 4
        sess = tr.daemon.sessions[tr.token]
        assert sess.counters["heartbeats"] >= 4  # batched windows landed
        assert tr.cp is sess.cp and tr.manager is sess.manager
        # failure drain goes through the protocol: deregister + tick
        tr.handle_failure([3])
        assert 3 not in tr.cp.members
        assert sess.counters["deregistered"] == 1
        # idempotent like the embedded path's mark_failed (pop-with-default)
        tr.handle_failure([3])
        assert sess.counters["deregistered"] == 1
        tr.add_members([3])
        assert 3 in tr.cp.members


class TestServeEngineDelegation:
    def test_engine_rebalance_via_daemon_session(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.models import model as Mo
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg = get_smoke_config("yi_6b")
        params = Mo.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(cfg, ServeConfig(n_replicas=2, lane_bits=1,
                                             max_len=64, rebalance_every=2,
                                             use_controld=True), params)
        for _ in range(4):
            eng.submit(np.arange(5), max_new_tokens=3)
        eng.run_until_done(max_ticks=60)
        assert eng.stats["completed"] == 4
        assert eng.daemon is not None
        sess = eng.daemon.sessions[eng.token]
        assert sess.counters["heartbeats"] > 0
        # the engine's manager/cp ARE the session's (one control plane)
        assert eng.manager is sess.manager


class TestDeregisterBatch:
    """DeregisterBatch drains a wave of members in one frame / one WAL
    entry, with per-member rejections — digest-identical to N scalar
    Deregisters at the same instant."""

    def _daemon(self, **kw):
        clk = _ManualClock()
        kw.setdefault("n_instances", 1)
        kw.setdefault("lease_s", 10.0)
        d = ControlDaemon(clock=kw.pop("clock", clk), **kw)
        d._test_clock = clk
        return d

    def test_batch_digest_equals_n_scalar_deregisters(self):
        daemons = [self._daemon(), self._daemon()]
        clients = [_client(d) for d in daemons]
        toks = []
        for c in clients:
            tok = c.reserve(policy="pid")["token"]
            c.register_batch(tok, range(6), lane_bits=1)
            c.tick(current_event=0)
            toks.append(tok)
        clients[0].deregister_batch(toks[0], [1, 3, 4])
        for m in (1, 3, 4):
            clients[1].deregister(toks[1], member_id=m)
        for c in clients:
            c.tick(current_event=600)
        assert daemons[0].state_digest() == daemons[1].state_digest()

    def test_per_member_rejection(self):
        d = self._daemon()
        c = _client(d)
        tok = c.reserve()["token"]
        c.register_batch(tok, range(4), lane_bits=1)
        c.tick(current_event=0)
        r = c.deregister_batch(tok, [0, 1, 1, 99, "x", 3])
        assert r["n_accepted"] == 3
        assert r["member_ids"] == [0, 1, 3]     # the duplicate 1 rejects
        assert set(r["rejected"]) == {"1", "99", "x"}
        s = next(iter(d.sessions.values()))
        assert s.counters["deregistered"] == 3
        assert sorted(s.cp.members) == [2]
        assert sorted(s.lanes.lease_ids()) == [2]

    def test_one_journal_entry_and_replay(self):
        j = Journal()
        d = self._daemon(journal=j)
        c = _client(d)
        tok = c.reserve()["token"]
        c.register_batch(tok, range(6), lane_bits=1)
        c.tick(current_event=0)
        c.deregister_batch(tok, [0, 2, 4])
        kinds = [e.kind for e in j.entries]
        assert kinds.count("deregister_batch") == 1
        assert "deregister" not in kinds
        rec = ControlDaemon.recover(j, n_instances=1, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()

    def test_pending_members_drain_before_start(self):
        d = self._daemon()
        c = _client(d)
        tok = c.reserve()["token"]
        c.register_batch(tok, range(4), lane_bits=1)
        # no tick yet: members are pending, not started
        r = c.deregister_batch(tok, [0, 1])
        assert r["n_accepted"] == 2
        c.tick(current_event=0)
        s = next(iter(d.sessions.values()))
        assert sorted(s.cp.members) == [2, 3]


class TestQuotas:
    """Per-reservation message-rate quotas: a token bucket refilled on the
    daemon clock; over-quota member messages are protocol rejections that
    replay identically from the WAL."""

    def _daemon(self, **kw):
        clk = _ManualClock()
        kw.setdefault("n_instances", 1)
        kw.setdefault("lease_s", 100.0)
        d = ControlDaemon(clock=kw.pop("clock", clk), **kw)
        d._test_clock = clk
        return d

    def test_over_quota_rejected_and_counted(self):
        d = self._daemon(quota_msgs_per_s=5.0, quota_burst=4.0)
        c = _client(d)
        tok = c.reserve()["token"]
        ok = rejected = 0
        for m in range(10):
            try:
                c.register(tok, member_id=m, node_id=m, lane_bits=1)
                ok += 1
            except ControldError as e:
                assert "quota" in str(e)
                rejected += 1
        assert ok == 4 and rejected == 6          # burst-bounded
        s = next(iter(d.sessions.values()))
        assert s.counters["quota_rejected"] == 6
        assert s.counters["registered"] == 4

    def test_bucket_refills_on_daemon_clock(self):
        d = self._daemon(quota_msgs_per_s=2.0, quota_burst=2.0)
        c = _client(d)
        tok = c.reserve()["token"]
        c.register(tok, member_id=0, node_id=0, lane_bits=1)
        c.register(tok, member_id=1, node_id=1, lane_bits=1)
        with pytest.raises(ControldError, match="quota"):
            c.register(tok, member_id=2, node_id=2, lane_bits=1)
        d._test_clock.t += 1.0                    # refills 2 tokens
        c.register(tok, member_id=2, node_id=2, lane_bits=1)
        c.register(tok, member_id=3, node_id=3, lane_bits=1)
        with pytest.raises(ControldError, match="quota"):
            c.register(tok, member_id=4, node_id=4, lane_bits=1)

    def test_batch_costs_one_token(self):
        d = self._daemon(quota_msgs_per_s=1.0, quota_burst=2.0)
        c = _client(d)
        tok = c.reserve()["token"]
        # one SendStateBatch of any width costs ONE token — batching is
        # exactly how a tenant stays inside its quota
        c.register_batch(tok, range(8), lane_bits=1)
        c.tick(current_event=0)
        c.send_state_batch(tok, range(8), [0.4] * 8)
        with pytest.raises(ControldError, match="quota"):
            c.send_state(tok, 0, fill=0.4)

    def test_quota_rejections_replay_digest_identical(self):
        j = Journal()
        d = self._daemon(quota_msgs_per_s=3.0, quota_burst=3.0, journal=j)
        c = _client(d)
        tok = c.reserve()["token"]
        for m in range(6):
            try:
                c.register(tok, member_id=m, node_id=m, lane_bits=1)
            except ControldError:
                pass
        d._test_clock.t += 0.5
        try:
            c.register(tok, member_id=6, node_id=6, lane_bits=1)
        except ControldError:
            pass
        c.tick(current_event=0)
        rec = ControlDaemon.recover(j, n_instances=1, lease_s=100.0,
                                    quota_msgs_per_s=3.0, quota_burst=3.0)
        assert rec.state_digest() == d.state_digest()
        s = next(iter(rec.sessions.values()))
        assert s.counters["quota_rejected"] == 3

    def test_no_quota_by_default(self):
        d = self._daemon()
        c = _client(d)
        tok = c.reserve()["token"]
        for m in range(64):
            c.register(tok, member_id=m, node_id=m, lane_bits=1)
        s = next(iter(d.sessions.values()))
        assert s.counters["quota_rejected"] == 0


class TestReserveFabric:
    """ReserveFabric claims 2K instances as K (spray, reserved) session
    pairs under one fabric id; Free unwinds membership."""

    def _daemon(self, **kw):
        clk = _ManualClock()
        kw.setdefault("n_instances", 8)
        kw.setdefault("lease_s", 10.0)
        d = ControlDaemon(clock=kw.pop("clock", clk), **kw)
        d._test_clock = clk
        return d

    def test_reserve_shape_and_instance_pairing(self):
        d = self._daemon()
        c = _client(d)
        r = c.reserve_fabric(k=3, reserved_fraction=0.5)
        assert r["k"] == 3 and len(r["sessions"]) == 3
        for lb, sess in enumerate(r["sessions"]):
            assert sess["lb"] == lb
            # instances pop in (lb, class) order: instance_id = lb*2 + class
            assert d.sessions[sess["spray"]].instance == 2 * lb
            assert d.sessions[sess["reserved"]].instance == 2 * lb + 1
        fid = r["fabric"]
        assert set(d.fabrics[fid]["tokens"]) == {
            s[t] for s in r["sessions"] for t in ("spray", "reserved")}

    def test_insufficient_instances_rejected_atomically(self):
        d = self._daemon(n_instances=4)
        c = _client(d)
        with pytest.raises(ControldError, match="instances"):
            c.reserve_fabric(k=3)
        assert not d.sessions and not d.fabrics   # nothing claimed

    def test_free_unwinds_fabric(self):
        d = self._daemon()
        c = _client(d)
        r = c.reserve_fabric(k=2)
        fid = r["fabric"]
        for sess in r["sessions"]:
            c.free(sess["spray"])
            c.free(sess["reserved"])
        assert fid not in d.fabrics
        assert len(d._free_instances) == 8

    def test_replay_digest_identical(self):
        j = Journal()
        d = self._daemon(journal=j)
        c = _client(d)
        r = c.reserve_fabric(k=2, policy="pid", reserved_fraction=0.25)
        for sess in r["sessions"]:
            c.register_batch(sess["spray"], range(4), lane_bits=1)
            c.register_batch(sess["reserved"], [4, 5], lane_bits=1)
        c.tick(current_event=0)
        c.free(r["sessions"][0]["spray"])
        rec = ControlDaemon.recover(j, n_instances=8, lease_s=10.0)
        assert rec.state_digest() == d.state_digest()
        assert rec.fabrics.keys() == d.fabrics.keys()
