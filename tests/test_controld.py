"""controld: session lifecycle, transports, journal replay, PID properties."""
import dataclasses
import os

import numpy as np
import pytest

from repro.controld import (ControlDaemon, ControldClient, ControldError,
                            InProcTransport, Journal, SocketClient,
                            SocketServer)
from repro.controld import messages as M
from repro.controld.policy import (PIDFillPolicy, PolicyConfig,
                                   ProportionalPolicy, make_policy)
from repro.core import route, split64
from repro.testing.hypo import given, settings, st


@dataclasses.dataclass
class _T:  # telemetry duck-type (MemberTelemetry fields)
    fill: float = 0.0
    rate: float = 1.0
    healthy: bool = True


def _daemon(**kw):
    kw.setdefault("n_instances", 2)
    kw.setdefault("lease_s", 10.0)
    kw.setdefault("epoch_horizon", 256)
    t = 0.0

    def clock():
        return t
    d = ControlDaemon(clock=kw.pop("clock", clock), **kw)
    return d


def _client(daemon):
    return ControldClient(InProcTransport(daemon))


class _ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestLifecycle:
    def test_reserve_register_heartbeat_free(self):
        d = _daemon(journal=Journal())
        c = _client(d)
        r = c.reserve(policy="proportional")
        assert r["instance"] == 0 and r["policy"] == "proportional"
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
        c.tick(current_event=0)
        assert d.sessions[r["token"]].started
        for m in range(4):
            out = c.send_state(r["token"], m, fill=0.5)
            assert out["lease_expires"] > 0
        freed = c.free(r["token"])
        assert freed["instance"] == 0
        assert d._free_instances == [0, 1]

    def test_token_scopes_all_member_calls(self):
        d = _daemon()
        c = _client(d)
        r = c.reserve()
        with pytest.raises(ControldError):
            c.register("r999999", member_id=0)
        with pytest.raises(ControldError):
            c.send_state("bogus", 0, fill=0.1)
        # a second tenant's token cannot touch the first tenant's members
        r2 = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        with pytest.raises(ControldError):
            c.send_state(r2["token"], 0, fill=0.1)

    def test_reservation_exhaustion_and_hint(self):
        d = _daemon(n_instances=2)
        c = _client(d)
        r1 = c.reserve(instance_hint=1)
        assert r1["instance"] == 1
        c.reserve()
        with pytest.raises(ControldError):
            c.reserve()
        c.free(r1["token"])
        assert c.reserve()["instance"] == 1

    def test_unknown_policy_is_rejected_and_instance_returned(self):
        d = _daemon(n_instances=1)
        c = _client(d)
        with pytest.raises(ControldError):
            c.reserve(policy="nonsense")
        assert c.reserve()["instance"] == 0  # instance was not leaked

    def test_bad_policy_param_rejected_without_poisoning_the_journal(self):
        """A non-numeric policy param (valid JSON!) must come back as a
        protocol rejection — not a TypeError that leaks the instance and,
        being journaled pre-execution, crashes every future recover()."""
        d = _daemon(n_instances=1, journal=Journal())
        c = _client(d)
        with pytest.raises(ControldError):
            c.reserve(policy="pid", policy_params={"kp": None})
        assert c.reserve()["instance"] == 0  # not leaked
        rec = ControlDaemon.recover(d.journal, n_instances=1,
                                    lease_s=10.0, epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_bad_register_fields_rejected_without_poisoning_the_journal(self):
        """weight=0/nan/inf and bad lane_bits are protocol-valid JSON that
        used to crash the *starting Tick* (after its WAL append) — they must
        be rejected at Register time and the journal must stay replayable."""
        d = _daemon(n_instances=1, journal=Journal())
        c = _client(d)
        r = c.reserve()
        for bad in (dict(weight=0.0), dict(weight=float("nan")),
                    dict(weight=float("inf")), dict(weight=-1.0),
                    dict(lane_bits=99)):
            with pytest.raises(ControldError):
                c.register(r["token"], member_id=0, node_id=0, **bad)
        c.register(r["token"], member_id=0, node_id=0)  # a good one
        c.tick(current_event=0)
        assert d.sessions[r["token"]].started
        rec = ControlDaemon.recover(d.journal, n_instances=1,
                                    lease_s=10.0, epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_deregister_drains_from_next_epoch(self):
        clk = _ManualClock()
        d = _daemon(clock=clk)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        c.deregister(r["token"], member_id=1)
        c.tick(current_event=500)  # membership delta -> epoch switch
        s = d.sessions[r["token"]]
        evs = np.arange(2000, 2512, dtype=np.uint64)
        hi, lo = split64(evs)
        routed = route(s.manager.device_tables(), hi, lo,
                       np.zeros(len(evs), np.uint32))
        assert 1 not in set(np.asarray(routed.member).tolist())


class TestLeases:
    def test_lease_expiry_drains_like_mark_failed(self):
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        # members 0 and 2 heartbeat; member 1 goes silent
        clk.t = 4.0
        c.send_state(r["token"], 0, fill=0.5)
        c.send_state(r["token"], 2, fill=0.5)
        clk.t = 6.0  # member 1's lease (granted at t=0) lapses
        tick = c.tick(current_event=1000)
        assert tick["sessions"][r["token"]]["expired"] == [1]
        s = d.sessions[r["token"]]
        assert 1 not in s.cp.members
        # heartbeats for a lapsed lease are rejected: re-register to rejoin
        with pytest.raises(ControldError):
            c.send_state(r["token"], 1, fill=0.1)
        c.register(r["token"], member_id=1, node_id=1)
        c.send_state(r["token"], 1, fill=0.1)
        c.tick(current_event=2000)
        assert 1 in s.cp.members

    def test_expiry_drain_is_hitless_for_inflight_epoch(self):
        """Satellite: a lease lapsing between schedule_epoch and the boundary
        must not disturb the in-flight epoch — old events keep routing to
        the lapsed member; only the post-boundary epoch excludes it."""
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0, epoch_horizon=400)
        c = _client(d)
        r = c.reserve()
        for m in range(3):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        s = d.sessions[r["token"]]
        # drive one reweight so an epoch boundary is scheduled ahead
        clk.t = 1.0
        for m in range(3):
            c.send_state(r["token"], m, fill=0.9 if m == 2 else 0.2)
        c.tick(current_event=100)  # schedules a boundary at ~500
        boundary = s.manager.records[s.manager.current_epoch].start_event
        assert boundary > 100
        # members 0/1 keep heart-beating; member 2 goes silent and its lease
        # lapses while that epoch is still in flight
        clk.t = 4.0
        c.send_state(r["token"], 0, fill=0.2)
        c.send_state(r["token"], 1, fill=0.2)
        clk.t = 6.5
        tick = c.tick(current_event=200)  # hysteresis: boundary still ahead
        assert tick["sessions"][r["token"]]["expired"] == [2]
        assert 2 not in s.cp.members
        # in-flight events (pre-boundary epochs) still route to member 2
        evs = np.arange(0, boundary, dtype=np.uint64)
        hi, lo = split64(evs)
        routed = route(s.manager.device_tables(), hi, lo,
                       np.zeros(len(evs), np.uint32))
        assert 2 in set(np.asarray(routed.member).tolist())
        # once traffic crosses the boundary, the next tick drains it
        c.tick(current_event=boundary + 10)
        evs2 = np.arange(boundary + 600, boundary + 1112, dtype=np.uint64)
        hi2, lo2 = split64(evs2)
        routed2 = route(s.manager.device_tables(), hi2, lo2,
                        np.zeros(len(evs2), np.uint32))
        assert 2 not in set(np.asarray(routed2.member).tolist())

    def test_late_heartbeat_rejected_even_before_a_tick_reaps(self):
        """The lease rule is independent of tick cadence: a heartbeat after
        the expiry instant is rejected even while the lease is still
        awaiting reaping by the next Tick."""
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=5.0)
        c = _client(d)
        r = c.reserve()
        c.register(r["token"], member_id=0, node_id=0)
        c.tick(current_event=0)
        clk.t = 6.0  # lapsed at t=5; no tick has run since
        with pytest.raises(ControldError):
            c.send_state(r["token"], 0, fill=0.3)
        tick = c.tick(current_event=100)
        assert tick["sessions"][r["token"]]["expired"] == [0]

    def test_all_leases_expired_keeps_last_epoch_live(self):
        clk = _ManualClock()
        d = _daemon(clock=clk, lease_s=2.0)
        c = _client(d)
        r = c.reserve()
        for m in range(2):
            c.register(r["token"], member_id=m, node_id=m)
        c.tick(current_event=0)
        clk.t = 10.0
        tick = c.tick(current_event=100)
        assert tick["sessions"][r["token"]]["expired"] == [0, 1]
        s = d.sessions[r["token"]]
        assert s.manager.current_epoch is not None  # no teardown


class TestTransportParity:
    SCRIPT = [
        M.Reserve(policy="pid", instance_hint=1),
        M.Register(token="r000000", member_id=0, node_id=0, lane_bits=1),
        M.Register(token="r000000", member_id=1, node_id=1),
        M.Tick(current_event=0),
        M.SendState(token="r000000", member_id=0, fill=0.8),
        M.SendState(token="r000000", member_id=1, fill=0.2),
        M.SendState(token="bogus", member_id=1, fill=0.2),  # rejection too
        M.Tick(current_event=600),
        M.Deregister(token="r000000", member_id=1),
        M.Tick(current_event=1200),
        M.Status(),
    ]

    def _play(self, transport, daemon):
        clk_out = []
        replies = []
        for msg in self.SCRIPT:
            r = transport.call(msg)
            replies.append((r.ok, r.error, r.data))
            clk_out.append(daemon.state_digest())
        return replies, clk_out

    def test_inproc_and_socket_property_equal(self):
        """The same message script through both transports produces
        identical replies AND identical daemon state at every step."""
        clk1, clk2 = _ManualClock(), _ManualClock()
        d1 = _daemon(clock=clk1)
        d2 = _daemon(clock=clk2)
        server = SocketServer(d2)
        host, port = server.start()
        try:
            sc = SocketClient(host, port)
            r1, s1 = self._play(InProcTransport(d1), d1)
            r2, s2 = self._play(sc, d2)
            sc.close()
        finally:
            server.stop()
        assert s1 == s2
        for (ok1, err1, data1), (ok2, err2, data2) in zip(r1, r2):
            assert (ok1, err1) == (ok2, err2)
            assert data1 == data2


class TestJournalReplay:
    def _workload(self, d):
        clk = d.clock
        c = _client(d)
        r = c.reserve(policy="pid", policy_params={"kd": 0.1})
        r2 = c.reserve(policy="proportional")
        for m in range(4):
            c.register(r["token"], member_id=m, node_id=m, lane_bits=1)
        c.register(r2["token"], member_id=0, node_id=10)
        c.tick(current_event=0)
        ev = 0
        for k in range(6):
            clk.t += 1.0
            for m in range(4):
                c.send_state(r["token"], m, fill=0.9 if m == 0 else 0.3)
            if k < 2:  # r2's member stops heart-beating after round 2
                c.send_state(r2["token"], 0, fill=0.4)
            ev += 400
            c.tick(current_event=ev)
        c.deregister(r["token"], member_id=3)
        clk.t = 11.0  # past r2's lease (renewed at t=2), within r's (t=6)
        c.tick(current_event=ev + 400)
        return d

    def test_replay_reproduces_byte_identical_state(self):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        recovered = ControlDaemon.recover(d.journal, n_instances=2,
                                          lease_s=8.0, epoch_horizon=256)
        assert recovered.state_digest() == d.state_digest()
        # calendars specifically must be byte-identical
        for token, s in d.sessions.items():
            s2 = recovered.sessions[token]
            assert set(s.manager.state.calendars) == set(s2.manager.state.calendars)
            for eid, cal in s.manager.state.calendars.items():
                assert cal.tobytes() == s2.manager.state.calendars[eid].tobytes()

    def test_recovered_daemon_keeps_working_and_journaling(self):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        seq = d.journal.seq
        rec = ControlDaemon.recover(d.journal, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256, clock=clk)
        c = ControldClient(InProcTransport(rec))
        token = sorted(rec.sessions)[0]
        c.send_state(token, 0, fill=0.5)
        assert rec.journal.seq == seq + 1  # seq-contiguous after recovery
        # ...and the twice-recovered daemon still matches
        rec2 = ControlDaemon.recover(rec.journal, n_instances=2, lease_s=8.0,
                                     epoch_horizon=256)
        assert rec2.state_digest() == rec.state_digest()

    def test_jsonl_roundtrip_and_torn_tail(self, tmp_path):
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        digest = d.state_digest()
        with open(path, "a") as f:
            f.write('{"seq": 9999, "kind": "tick", "payl')  # torn append
        loaded = Journal.load(path)
        assert loaded.seq == d.journal.seq  # torn line dropped
        rec = ControlDaemon.recover(loaded, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == digest

    def test_recover_from_file_keeps_persisting_without_duplication(
            self, tmp_path):
        """Recovering from an on-disk journal continues appending to the
        same file seq-contiguously — a second recovery sees ONE history,
        never a duplicated prefix (the --serve restart path)."""
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        d.journal.close()
        rec = ControlDaemon.recover(Journal.load(path), n_instances=2,
                                    lease_s=8.0, epoch_horizon=256, clock=clk)
        assert rec.state_digest() == d.state_digest()
        token = sorted(rec.sessions)[0]
        ControldClient(InProcTransport(rec)).send_state(token, 0, fill=0.5)
        rec.journal.close()
        reloaded = Journal.load(path)
        seqs = [e.seq for e in reloaded.entries]
        assert seqs == list(range(len(seqs)))  # one contiguous history
        rec2 = ControlDaemon.recover(reloaded, n_instances=2, lease_s=8.0,
                                     epoch_horizon=256)
        assert rec2.state_digest() == rec.state_digest()

    def test_snapshot_restore(self, tmp_path):
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal()))
        snap_dir = str(tmp_path / "snaps")
        d.journal.snapshot(snap_dir)
        j = Journal.restore(snap_dir)
        rec = ControlDaemon.recover(j, n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()

    def test_file_backed_journal_memory_stays_bounded(self, tmp_path):
        """A journal mirrored to disk must not also retain every heartbeat
        in RAM (a --serve daemon journals forever); the file is the replay
        source, and snapshot() reads it from there."""
        path = os.path.join(tmp_path, "journal.jsonl")
        clk = _ManualClock()
        d = self._workload(_daemon(clock=clk, lease_s=8.0,
                                   journal=Journal(path)))
        assert d.journal.entries == []          # disk-only retention
        assert d.journal.seq > 0
        snap_dir = str(tmp_path / "snaps")
        d.journal.snapshot(snap_dir)            # snapshots from the file
        rec = ControlDaemon.recover(Journal.restore(snap_dir),
                                    n_instances=2, lease_s=8.0,
                                    epoch_horizon=256)
        assert rec.state_digest() == d.state_digest()


class TestPIDProperties:
    """Hypothesis properties for the PID fill policy (satellite task)."""

    @settings(max_examples=40)
    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=6),
           st.integers(min_value=1, max_value=30))
    def test_weights_always_normalized_and_nonnegative(self, fills, steps):
        pol = PIDFillPolicy(PolicyConfig(kd=0.2))
        n = len(fills)
        pol.reset(range(n))
        w = {m: 1.0 for m in range(n)}
        for _ in range(steps):
            w = pol.update(w, {m: _T(fill=fills[m]) for m in range(n)})
            for v in w.values():
                assert v >= 0.0
            live = [v for v in w.values() if v > 0]
            assert live, "policy drove every member to zero"
            for v in live:
                assert pol.cfg.min_weight <= v <= pol.cfg.max_weight

    @settings(max_examples=25)
    @given(st.floats(min_value=0.6, max_value=1.0),
           st.integers(min_value=50, max_value=400))
    def test_anti_windup_bounds_integral_under_saturation(self, fill, steps):
        # huge integral_limit: only back-calculation can bound the windup
        cfg = PolicyConfig(kd=0.0, integral_limit=100.0, output_limit=0.5)
        pol = PIDFillPolicy(cfg)
        pol.reset(range(2))
        w = {0: 1.0, 1: 1.0}
        err = cfg.target_fill - fill  # sustained negative error
        for _ in range(steps):
            w = pol.update(w, {0: _T(fill=fill), 1: _T(fill=cfg.target_fill)})
        bound = cfg.output_limit + cfg.kp * abs(err) + 1e-9
        assert abs(pol._integral[0]) <= bound
        # without anti-windup the clip would be the only bound (=100)
        assert abs(pol._integral[0]) < cfg.integral_limit

    @settings(max_examples=25)
    @given(st.lists(st.floats(min_value=0.1, max_value=4.0),
                    min_size=2, max_size=6),
           st.integers(min_value=1, max_value=10))
    def test_zero_error_reproduces_proportional_fixed_point(self, w0, steps):
        """At setpoint fill, PID and proportional converge to the same
        fixed point (the normalized clip of the weights) — the PID is a
        strict generalization, not a different equilibrium."""
        cfg = PolicyConfig(kd=0.3)
        pid, prop = PIDFillPolicy(cfg), ProportionalPolicy(cfg)
        n = len(w0)
        pid.reset(range(n))
        prop.reset(range(n))
        w1 = {m: w0[m] for m in range(n)}
        w2 = {m: w0[m] for m in range(n)}
        tele = {m: _T(fill=cfg.target_fill) for m in range(n)}
        for _ in range(steps):
            w1 = pid.update(w1, tele)
            w2 = prop.update(w2, tele)
        assert w1 == w2
        # and it IS a fixed point: one more step changes nothing (up to the
        # renormalization's float round-trip, mean(w)/1.0 == 1 ± 1 ulp)
        w_next = pid.update(dict(w1), tele)
        assert w_next == pytest.approx(w1, rel=1e-12)

    def test_unhealthy_member_goes_to_zero_both_policies(self):
        for pol in (PIDFillPolicy(), ProportionalPolicy()):
            pol.reset(range(3))
            w = pol.update({0: 1.0, 1: 1.0, 2: 1.0},
                           {0: _T(fill=0.5), 1: _T(fill=0.5, healthy=False),
                            2: _T(fill=0.5)})
            assert w[1] == 0.0 and w[0] > 0 and w[2] > 0

    def test_make_policy_rejects_unknown_params(self):
        with pytest.raises(ValueError):
            make_policy("pid", {"kq": 1.0})
        with pytest.raises(ValueError):
            make_policy("banana")


class TestServeEngineDelegation:
    def test_engine_rebalance_via_daemon_session(self):
        import jax

        from repro.configs import get_smoke_config
        from repro.models import model as Mo
        from repro.serve.engine import ServeConfig, ServingEngine

        cfg = get_smoke_config("yi_6b")
        params = Mo.init_params(jax.random.PRNGKey(0), cfg)
        eng = ServingEngine(cfg, ServeConfig(n_replicas=2, lane_bits=1,
                                             max_len=64, rebalance_every=2,
                                             use_controld=True), params)
        for _ in range(4):
            eng.submit(np.arange(5), max_new_tokens=3)
        eng.run_until_done(max_ticks=60)
        assert eng.stats["completed"] == 4
        assert eng.daemon is not None
        sess = eng.daemon.sessions[eng.token]
        assert sess.counters["heartbeats"] > 0
        # the engine's manager/cp ARE the session's (one control plane)
        assert eng.manager is sess.manager
