"""Property tests for the unified DataPlane facade.

The acceptance bar for the refactor: backend="pallas" ≡ backend="jnp" ≡ the
naive per-instance reference, on fuzzed tables and headers, for both the
single-instance and the stacked multi-instance (fused gather) paths; and the
sort-based dispatch plan preserves the historical cumsum-of-one-hot
semantics including drop accounting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (DataPlane, EpochManager, MemberSpec, dispatch,
                        encode_headers, member_positions)
from repro.core.dataplane import resolve_backend
from repro.core.instance import VirtualLoadBalancer
from repro.kernels import ref
from repro.testing.hypo import given, settings, st


def _fuzz_manager(seed: int, n_members: int, reconfig: bool) -> EpochManager:
    rng = np.random.default_rng(seed)
    em = EpochManager(max_members=32)
    em.initialize(
        {i: MemberSpec(node_id=int(rng.integers(0, 32)),
                       base_lane=int(rng.integers(0, 64)),
                       lane_bits=int(rng.integers(0, 4)))
         for i in range(n_members)},
        {i: float(rng.uniform(0.1, 4.0)) for i in range(n_members)})
    if reconfig:
        k = int(rng.integers(1, n_members + 1))
        em.reconfigure({i: MemberSpec(node_id=i) for i in range(k)},
                       {i: 1.0 for i in range(k)},
                       boundary_event=int(rng.integers(1, 1 << 20)))
    return em


def _fuzz_headers(seed: int, n: int, corrupt: bool):
    rng = np.random.default_rng(seed + 1)
    ev = rng.integers(0, 1 << 62, n).astype(np.uint64)
    en = rng.integers(0, 1 << 16, n).astype(np.uint32)
    h = encode_headers(ev, en)
    if corrupt and n > 2:
        h[:: max(n // 7, 1), 0] ^= 0x1_0000
    return h


def _assert_routes_equal(a, b, ctx=""):
    np.testing.assert_array_equal(np.asarray(a.member), np.asarray(b.member), ctx)
    np.testing.assert_array_equal(np.asarray(a.node), np.asarray(b.node), ctx)
    np.testing.assert_array_equal(np.asarray(a.lane), np.asarray(b.lane), ctx)
    np.testing.assert_array_equal(
        np.asarray(a.valid).astype(np.int32),
        np.asarray(b.valid).astype(np.int32), ctx)


class TestBackendParity:
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 700),
           n_members=st.integers(1, 12))
    @settings(max_examples=15)
    def test_single_instance(self, seed, n, n_members):
        em = _fuzz_manager(seed, n_members, reconfig=seed % 3 == 0)
        h = jnp.asarray(_fuzz_headers(seed, n, corrupt=seed % 2 == 0))
        r_jnp = DataPlane.from_manager(em, backend="jnp").route(h)
        r_pal = DataPlane.from_manager(em, backend="pallas",
                                       interpret=True).route(h)
        _assert_routes_equal(r_jnp, r_pal)
        # both equal the kernel oracle (core/router reference semantics)
        m, nd, ln, v = ref.lb_route_ref(h, em.device_tables())
        np.testing.assert_array_equal(np.asarray(r_jnp.member), np.asarray(m))
        np.testing.assert_array_equal(np.asarray(r_jnp.valid).astype(np.int32),
                                      np.asarray(v))

    @given(seed=st.integers(0, 10_000), n=st.integers(1, 500))
    @settings(max_examples=10)
    def test_multi_instance(self, seed, n):
        """Fused single-pass gather ≡ naive per-instance route-and-select,
        on both backends."""
        rng = np.random.default_rng(seed)
        vlb = VirtualLoadBalancer(max_members=32)
        for k in range(4):
            nm = int(rng.integers(1, 6))
            vlb.instances[k].initialize(
                {i: MemberSpec(node_id=100 * k + i,
                               base_lane=int(rng.integers(0, 32)),
                               lane_bits=int(rng.integers(0, 3)))
                 for i in range(nm)},
                {i: float(rng.uniform(0.2, 3.0)) for i in range(nm)})
        stacked = vlb.device_tables()
        h = jnp.asarray(_fuzz_headers(seed, n, corrupt=seed % 2 == 1))
        iid = jnp.asarray(rng.integers(0, 4, n), jnp.int32)

        want = ref.lb_route_ref(h, stacked, iid)  # naive per-instance oracle
        for backend in ("jnp", "pallas"):
            dp = DataPlane(stacked, backend=backend, interpret=True)
            r = dp.route(h, iid)
            got = (r.member, r.node, r.lane, r.valid.astype(jnp.int32))
            for g, w in zip(got, want):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                              backend)

    def test_route_events_matches_route(self):
        em = _fuzz_manager(7, 5, reconfig=True)
        ev = np.arange(100, dtype=np.uint64) * 37
        en = (np.arange(100) % 11).astype(np.uint32)
        dp = DataPlane.from_manager(em, backend="jnp")
        r1 = dp.route_events(ev, en)
        r2 = dp.route(jnp.asarray(encode_headers(ev, en)))
        _assert_routes_equal(r1, r2)

    def test_backend_validation(self):
        em = _fuzz_manager(0, 2, reconfig=False)
        with pytest.raises(ValueError):
            DataPlane.from_manager(em, backend="fpga").route(
                jnp.zeros((4, 4), jnp.uint32))
        with pytest.raises(ValueError):
            DataPlane.from_manager(em).route(jnp.zeros((4, 3), jnp.uint32))
        # instance_id demanded iff tables are stacked
        with pytest.raises(ValueError):
            DataPlane.from_manager(em).route(jnp.zeros((4, 4), jnp.uint32),
                                             jnp.zeros(4, jnp.int32))
        assert resolve_backend("auto") in ("jnp", "pallas")


class TestRouteWindowEdges:
    """Host arrival-window routing: empty windows, exact power-of-two sizes,
    and the padding rule (zero-magic rows can never alias a real packet)."""

    def _batch(self, n: int, seed: int = 0):
        from repro.data.daq import DAQConfig, DAQFleet
        from repro.data.segmentation import segment_bundles

        fleet = DAQFleet(DAQConfig(n_daqs=1, mean_bundle_bytes=900,
                                   seed=seed))
        batch = segment_bundles(fleet.bundle_window(max(n, 1)), 2048)
        assert len(batch) >= n
        return batch.take(np.arange(n))

    def test_empty_window(self):
        em = _fuzz_manager(1, 3, reconfig=False)
        dp = DataPlane.from_manager(em, backend="jnp")
        member, node, lane, valid = dp.route_window(self._batch(0))
        for arr in (member, node, lane, valid):
            assert arr.shape == (0,)

    def test_exact_power_of_two_window(self):
        em = _fuzz_manager(2, 4, reconfig=False)
        dp = DataPlane.from_manager(em, backend="jnp")
        for n in (16, 32, 64):
            batch = self._batch(n)
            member, _node, _lane, valid = dp.route_window(batch)
            assert member.shape == (n,) and valid.shape == (n,)
            assert valid.all()  # no padding row leaks into the window

    @given(n=st.integers(1, 70), seed=st.integers(0, 50))
    @settings(max_examples=20)
    def test_padding_rows_never_valid(self, n, seed):
        """Windows of any size route exactly n results, and the zero-magic
        padding rows the facade adds can never produce valid=True."""
        em = _fuzz_manager(seed, 3, reconfig=False)
        dp = DataPlane.from_manager(em, backend="jnp")
        batch = self._batch(n, seed)
        member, _node, _lane, valid = dp.route_window(batch)
        assert valid.shape == (n,) and valid.all()
        # the padding representation itself: zero words fail validation
        from repro.data.segmentation import next_pow2

        pad = jnp.zeros((next_pow2(n), 4), jnp.uint32)
        r = dp.route(pad)
        assert not np.asarray(r.valid).any()
        assert (np.asarray(r.member) == -1).all()


def _onehot_positions(member, n_members, capacity):
    """The pre-refactor cumsum-of-one-hot semantics (historical reference)."""
    onehot = jax.nn.one_hot(member, n_members, dtype=jnp.int32)
    pos_in_member = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.sum(pos_in_member * onehot, axis=-1)
    counts = jnp.sum(onehot, axis=0)
    keep = (member >= 0) & (pos < capacity)
    return pos, keep, counts


class TestSortDispatchSemantics:
    @given(seed=st.integers(0, 10_000), n=st.integers(1, 2000),
           n_members=st.integers(1, 24), capacity=st.integers(1, 200))
    @settings(max_examples=20)
    def test_matches_onehot_cumsum(self, seed, n, n_members, capacity):
        rng = np.random.default_rng(seed)
        member = jnp.asarray(np.where(rng.random(n) < 0.1, -1,
                                      rng.integers(0, n_members, n))
                             .astype(np.int32))
        pos, keep, counts = member_positions(member, n_members, capacity)
        pos0, keep0, counts0 = _onehot_positions(member, n_members, capacity)
        np.testing.assert_array_equal(np.asarray(pos), np.asarray(pos0))
        np.testing.assert_array_equal(np.asarray(keep), np.asarray(keep0))
        np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts0))

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10)
    def test_drop_accounting_preserved(self, seed):
        """Every packet either lands exactly once or is accounted a drop."""
        rng = np.random.default_rng(seed)
        n, m, cap = 600, 7, 30
        member = jnp.asarray(np.where(rng.random(n) < 0.15, -1,
                                      rng.integers(0, m, n)).astype(np.int32))
        payload = jnp.asarray(rng.normal(size=(n, 4)).astype(np.float32))
        buf, occ, counts = dispatch(payload, member, m, cap)
        landed = int(occ.sum())
        dropped = int(np.maximum(np.asarray(counts) - cap, 0).sum())
        assert landed + dropped == int((np.asarray(member) >= 0).sum())
        # arrival order within a member is preserved (stable pack)
        pos, keep, _ = member_positions(member, m, cap)
        mm, pp = np.asarray(member), np.asarray(pos)
        for mid in range(m):
            sel = pp[(mm == mid)]
            np.testing.assert_array_equal(np.sort(sel), np.arange(len(sel)))

    def test_large_n_beyond_int32_key_range(self):
        """n >= 46341 (n^2 overflows int32): the un-permute must fall back
        to the scatter path and stay exact."""
        rng = np.random.default_rng(11)
        n, m = 50_000, 8
        member_np = np.where(rng.random(n) < 0.1, -1,
                             rng.integers(0, m, n)).astype(np.int32)
        pos, keep, counts = member_positions(jnp.asarray(member_np), m, 10_000)
        ref_pos = np.zeros(n, np.int64)
        running: dict[int, int] = {}
        for idx, mm in enumerate(member_np):
            if mm >= 0:
                ref_pos[idx] = running.get(mm, 0)
                running[mm] = running.get(mm, 0) + 1
        sel = member_np >= 0
        np.testing.assert_array_equal(np.asarray(pos)[sel], ref_pos[sel])
        assert all(int(counts[k]) == running.get(k, 0) for k in range(m))

    def test_plan_parity_jnp_vs_pallas(self):
        rng = np.random.default_rng(3)
        member = jnp.asarray(np.where(rng.random(1500) < 0.05, -1,
                                      rng.integers(0, 9, 1500)).astype(np.int32))
        em = _fuzz_manager(3, 4, reconfig=False)
        p1, c1 = DataPlane.from_manager(em, backend="jnp").plan(member, 9)
        p2, c2 = DataPlane.from_manager(em, backend="pallas",
                                        interpret=True).plan(member, 9)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
