import os

# Tests and benches must see the real device topology (1 CPU device), never
# the dry-run's 512 placeholder devices. Multi-device tests spawn their own
# subprocess with XLA_FLAGS (tests/test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional (repro.testing.hypo falls back to seeded random
# sampling); register the CI profile only when the real library is present.
try:
    from hypothesis import settings
except ImportError:
    pass
else:
    settings.register_profile("ci", max_examples=40, deadline=None)
    settings.load_profile("ci")
