import os

# Tests and benches must see the real device topology (1 CPU device), never
# the dry-run's 512 placeholder devices. Multi-device tests spawn their own
# subprocess with XLA_FLAGS (tests/test_distributed.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")
