"""Launch-layer units: input specs, shard-spec tables, mesh views.

(The full 512-device lower+compile path is exercised by
`python -m repro.launch.dryrun` — artifacts in artifacts/dryrun; these tests
cover the spec builders on the in-process single-device view.)
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import shapes as SH
from repro.launch.shardspecs import decode_state_shardings


class TestBatchSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_train_specs_complete(self, arch):
        cfg = get_config(arch)
        specs = SH.batch_specs(cfg, "train_4k")
        assert "labels" in specs and "headers" in specs
        assert specs["headers"].shape == (256, 4)
        assert specs["headers"].dtype == jnp.uint32
        if cfg.family == "audio":
            assert specs["embeds"].shape == (256, 4096, cfg.d_model)
        else:
            assert specs["tokens"].shape == (256, 4096)
        if cfg.family == "vlm":
            assert specs["vision_embeds"].shape[1] == cfg.n_vision_tokens

    def test_decode_specs_single_token(self):
        cfg = get_config("yi_6b")
        specs = SH.batch_specs(cfg, "decode_32k")
        assert specs == {"tokens": jax.ShapeDtypeStruct((128,), jnp.int32)}

    @pytest.mark.parametrize("arch,shape", [
        ("yi_6b", "decode_32k"), ("mixtral_8x22b", "long_500k"),
        ("zamba2_2_7b", "long_500k"), ("rwkv6_7b", "decode_32k"),
        ("llama_3_2_vision_90b", "decode_32k"),
    ])
    def test_decode_state_specs_and_shardings(self, arch, shape):
        cfg = get_config(arch)
        state = SH.decode_state_specs(cfg, shape)
        # cache depth honors SWA windows (ring) vs full length
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        shardings = decode_state_shardings(cfg, mesh, state)
        leaves_state = jax.tree.leaves(state)
        leaves_shard = jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(leaves_state) == len(leaves_shard)
        for spec_leaf, st_leaf in zip(leaves_shard, leaves_state):
            assert len(spec_leaf.spec) <= st_leaf.ndim

    def test_swa_ring_cache_bounded(self):
        cfg = get_config("mixtral_8x22b")
        state = SH.decode_state_specs(cfg, "long_500k")
        # ring cache = window, NOT 524288 (that's the sub-quadratic point)
        assert state["kv"].k.shape[2] == cfg.swa_window

    def test_rwkv_state_is_o1(self):
        cfg = get_config("rwkv6_7b")
        state = SH.decode_state_specs(cfg, "long_500k")
        total = sum(x.size for x in jax.tree.leaves(state))
        assert total < 50e6  # O(1) in context length


class TestMeshViews:
    def test_production_and_variant_shapes(self):
        # shape math only — construction needs >=256 devices (dry-run only)
        from repro.launch import mesh as MM
        import inspect
        src = inspect.getsource(MM.make_production_mesh)
        assert "(2, 16, 16)" in src and "(16, 16)" in src
        src = inspect.getsource(MM.make_hybrid_mesh)
        assert "256 // tp" in src
