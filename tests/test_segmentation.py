"""Segmentation & reassembly under reorder/loss/duplication (paper §II-C)."""
import numpy as np
from repro.testing.hypo import given, settings, st

from repro.data.daq import DAQConfig, DAQFleet, EventBundle
from repro.data.segmentation import Reassembler, segment_bundle
from repro.data.transport import TransportConfig, WANTransport


def _bundle(nbytes, ev=7, daq=0, entropy=3):
    rng = np.random.default_rng(ev)
    return EventBundle(ev, daq, entropy,
                       rng.integers(0, 256, nbytes).astype(np.uint8))


class TestSegmentation:
    @given(nbytes=st.integers(1, 100_000))
    @settings(max_examples=25)
    def test_roundtrip(self, nbytes):
        b = _bundle(nbytes)
        segs = segment_bundle(b)
        ra = Reassembler()
        out = None
        for s in segs:
            got = ra.push(s)
            if got is not None:
                out = got
        assert out is not None and np.array_equal(out, b.payload)

    def test_segments_fit_mtu(self):
        from repro.core.protocol import MAX_PACKET_BYTES
        segs = segment_bundle(_bundle(100_000))
        for s in segs:
            assert len(s.payload) + 16 + 28 + 8 <= MAX_PACKET_BYTES

    def test_common_event_and_entropy(self):
        """All segments of a bundle share (Event#, Entropy) => same CN+lane."""
        segs = segment_bundle(_bundle(50_000, ev=42, entropy=9))
        assert all(s.event_number == 42 and s.entropy == 9 for s in segs)

    @given(seed=st.integers(0, 1000))
    @settings(max_examples=20)
    def test_reorder_immune(self, seed):
        b = _bundle(60_000)
        segs = segment_bundle(b)
        wan = WANTransport(TransportConfig(reorder_window=64, seed=seed))
        ra = Reassembler()
        out = None
        for s in wan.deliver(segs):
            got = ra.push(s)
            if got is not None:
                out = got
        assert out is not None and np.array_equal(out, b.payload)

    def test_loss_detected_never_corrupts(self):
        b = _bundle(80_000)
        segs = segment_bundle(b)
        wan = WANTransport(TransportConfig(loss_prob=0.3, seed=1))
        ra = Reassembler()
        outs = [ra.push(s) for s in wan.deliver(segs)]
        done = [o for o in outs if o is not None]
        if wan.n_lost > 0:
            assert not done and ra.n_incomplete == 1
        for o in done:
            assert np.array_equal(o, b.payload)

    def test_duplicates_idempotent(self):
        b = _bundle(40_000)
        segs = segment_bundle(b)
        ra = Reassembler()
        out = None
        for s in segs + segs[:3]:
            got = ra.push(s)
            if got is not None:
                out = got
        assert np.array_equal(out, b.payload)
        assert ra.n_duplicate >= 0  # late dup after completion opens new buf

    def test_interleaved_events_and_daqs(self):
        """Multiple DAQs x multiple events interleaved arbitrarily."""
        bundles = [_bundle(30_000 + 1000 * d, ev=e, daq=d)
                   for e in range(3) for d in range(4)]
        segs = [s for b in bundles for s in segment_bundle(b)]
        rng = np.random.default_rng(0)
        rng.shuffle(segs)
        ra = Reassembler()
        for s in segs:
            ra.push(s)
        assert len(ra.completed) == 12 and ra.n_incomplete == 0


class TestDAQ:
    def test_monotone_event_numbers(self):
        fleet = DAQFleet(DAQConfig(n_daqs=3))
        evs = [bs[0].event_number for bs in fleet.stream(100)]
        assert all(b > a for a, b in zip(evs, evs[1:]))

    def test_trigger_synchronization(self):
        """All DAQs observing one trigger carry the same event number."""
        fleet = DAQFleet(DAQConfig(n_daqs=5))
        for bundles in fleet.stream(10):
            assert len({b.event_number for b in bundles}) == 1

    def test_lsb_uniformity(self):
        """9 LSBs must be ~uniform (paper §II-A requirement)."""
        fleet = DAQFleet(DAQConfig(n_daqs=1))
        evs = np.array([bs[0].event_number for bs in fleet.stream(4000)])
        slots = evs & 0x1FF
        counts = np.bincount(slots % 8)
        assert counts.min() > 0.7 * counts.max()
