"""repro.fabric invariants: VLB spray determinism, two-hop conservation,
elephant hysteresis, lane isolation, and hit-less tier-member failure."""
import dataclasses

import numpy as np
import pytest

from repro.fabric import (ElephantConfig, ElephantDetector, FabricConfig,
                          FabricSim, get_fabric_scenario, mix64, spray_keys,
                          spray_paths)
from repro.simnet.links import LinkConfig


def _report_key(report):
    """Everything a rerun must reproduce (wall time excluded)."""
    d = report.to_dict()
    d.pop("wall_s")
    d.pop("packets_per_sec")
    return d


def _lossless_cfg(**kw):
    base = dict(
        steps=12, k_lbs=3, n_members=9, n_daqs=4, triggers_per_step=3,
        mean_bundle_bytes=6_000, seed=5,
        daq_uplink=LinkConfig(rate_Bps=0.0),
        lb_ingress=LinkConfig(rate_Bps=0.0),
        lb_fabric=LinkConfig(rate_Bps=0.0),
        member_link=LinkConfig(rate_Bps=0.0),
        queue_capacity_s=100.0,
    )
    base.update(kw)
    return FabricConfig(**base)


class TestSprayKeys:
    def test_deterministic_under_fixed_seed(self):
        ev = np.arange(1, 2001, dtype=np.uint64)
        dq = (np.arange(2000) % 7).astype(np.uint64)
        b1, o1 = spray_keys(ev, dq, seed=42)
        b2, o2 = spray_keys(ev, dq, seed=42)
        np.testing.assert_array_equal(b1, b2)
        np.testing.assert_array_equal(o1, o2)
        b3, o3 = spray_keys(ev, dq, seed=43)
        assert (b1 != b3).any() and (o1 != o3).any()

    def test_owner_key_ignores_daq(self):
        # fabric-wide event affinity: the owner is a function of the event
        # number alone, whichever DAQ emitted the bundle
        ev = np.arange(1, 501, dtype=np.uint64)
        _, o_a = spray_keys(ev, np.zeros(500, np.uint64), seed=1)
        _, o_b = spray_keys(ev, np.full(500, 6, np.uint64), seed=1)
        np.testing.assert_array_equal(o_a, o_b)
        # ...while phase-1 spray decorrelates across DAQs
        b_a, _ = spray_keys(ev, np.zeros(500, np.uint64), seed=1)
        b_b, _ = spray_keys(ev, np.full(500, 6, np.uint64), seed=1)
        assert (b_a != b_b).any()

    def test_vlb_spreads_uniformly(self):
        ev = np.arange(1, 20001, dtype=np.uint64)
        dq = np.zeros(20000, np.uint64)     # ONE hot DAQ
        inter, owner, _ = spray_paths(ev, dq, list(range(4)), mode="vlb")
        for arr in (inter, owner):
            frac = np.bincount(arr, minlength=4) / len(arr)
            assert frac.max() < 0.30        # ~0.25 each despite total skew

    def test_direct_concentrates(self):
        ev = np.arange(1, 1001, dtype=np.uint64)
        dq = np.zeros(1000, np.uint64)
        inter, owner, _ = spray_paths(ev, dq, list(range(4)), mode="direct")
        assert (inter == owner).all()
        assert len(np.unique(inter)) == 1   # the hot DAQ pins one LB

    def test_live_set_reindex_is_deterministic(self):
        ev = np.arange(1, 301, dtype=np.uint64)
        dq = (np.arange(300) % 3).astype(np.uint64)
        full = spray_paths(ev, dq, [0, 1, 2, 3], seed=9)
        a = spray_paths(ev, dq, [0, 2, 3], seed=9)
        b = spray_paths(ev, dq, [0, 2, 3], seed=9)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
        assert not np.isin(a[0], [1]).any() and not np.isin(a[1], [1]).any()
        assert (a[0] != full[0]).any()      # re-spray really re-indexes

    def test_errors(self):
        ev = np.ones(4, np.uint64)
        dq = np.zeros(4, np.uint64)
        with pytest.raises(ValueError, match="no live"):
            spray_paths(ev, dq, [])
        with pytest.raises(ValueError, match="unknown spray mode"):
            spray_paths(ev, dq, [0], mode="rotor")

    def test_mix64_is_a_permutation_locally(self):
        x = np.arange(100_000, dtype=np.uint64)
        assert len(np.unique(mix64(x))) == len(x)


class TestElephantDetector:
    def test_promotes_and_demotes_through_hysteresis(self):
        det = ElephantDetector(1, ElephantConfig(hi_Bps=30e6, lo_Bps=15e6,
                                                 alpha=1.0))
        mask = det.update([40e6], 1.0)
        assert mask[0] and det.elephant[0]   # above hi -> elephant
        det.update([10e6], 1.0)
        assert not det.elephant[0]           # below lo -> mouse
        assert det.transitions == 2

    def test_no_flap_inside_the_band(self):
        # rates oscillating INSIDE (lo, hi) never change class: one
        # promotion, then zero transitions however long it hovers
        det = ElephantDetector(1, ElephantConfig(hi_Bps=30e6, lo_Bps=15e6,
                                                 alpha=1.0))
        det.update([40e6], 1.0)
        for i in range(50):
            det.update([20e6 if i % 2 else 28e6], 1.0)
            assert det.elephant[0]
        assert det.transitions == 1
        # and a mouse hovering in the band stays a mouse
        det2 = ElephantDetector(1, ElephantConfig(hi_Bps=30e6, lo_Bps=15e6,
                                                  alpha=1.0))
        for i in range(50):
            det2.update([20e6 if i % 2 else 28e6], 1.0)
        assert not det2.elephant[0] and det2.transitions == 0

    def test_ewma_smooths_spikes(self):
        # one-window spike above hi doesn't promote when alpha damps it
        det = ElephantDetector(1, ElephantConfig(hi_Bps=30e6, lo_Bps=15e6,
                                                 alpha=0.2))
        det.update([50e6], 1.0)              # EWMA = 10e6 < hi
        assert not det.elephant[0]

    def test_validation(self):
        with pytest.raises(ValueError, match="hi_Bps > lo_Bps"):
            ElephantConfig(hi_Bps=1.0, lo_Bps=2.0)
        with pytest.raises(ValueError, match="alpha"):
            ElephantConfig(alpha=0.0)
        det = ElephantDetector(4)
        with pytest.raises(ValueError, match="byte counts"):
            det.update(np.zeros(3), 1.0)


class TestConservation:
    def test_lossless_two_hop_serves_everything(self):
        r = FabricSim(_lossless_cfg()).run()
        assert r.violations == []
        assert r.segments_served == r.segments_sent
        assert r.bundles_completed == r.bundles_sent
        assert (r.lost_uplink == r.lost_ingress == r.lost_fabric
                == r.discarded_invalid == r.lost_downlink
                == r.dropped_queue == 0)

    def test_lossy_links_still_account_every_segment(self):
        cfg = _lossless_cfg(
            daq_uplink=LinkConfig(rate_Bps=0.0, loss_prob=0.03, seed=1),
            lb_fabric=LinkConfig(rate_Bps=0.0, loss_prob=0.05, seed=2),
            member_link=LinkConfig(rate_Bps=0.0, loss_prob=0.03, seed=3))
        r = FabricSim(cfg).run()
        # the conservation identity is audited inside run(); a clean
        # violations list IS the sent == served + sum(losses) proof
        assert r.violations == []
        assert r.lost_uplink > 0 and r.lost_fabric > 0
        assert r.lost_downlink > 0
        assert r.bundles_completed + r.bundles_lost == r.bundles_sent
        assert r.segments_served < r.segments_sent

    def test_direct_mode_never_takes_the_fabric_hop(self):
        r = FabricSim(_lossless_cfg(
            mode="direct",
            lb_fabric=LinkConfig(rate_Bps=0.0, loss_prob=1.0))).run()
        assert r.violations == []
        assert r.lost_fabric == 0            # no two-hop rows exist


class TestScenarioGates:
    def test_vlb_beats_direct_on_max_lb_load(self):
        sc = get_fabric_scenario("vlb_spray")
        vlb = FabricSim(sc.build_config(mode="vlb"), scenario=sc).run()
        direct = FabricSim(sc.build_config(mode="direct"), scenario=sc).run()
        assert vlb.violations == [] and direct.violations == []
        assert vlb.max_lb_load_frac <= direct.max_lb_load_frac
        # the skew is real: direct pins the hot DAQ on one LB
        assert direct.max_lb_load_frac > 1.5 / direct.k_lbs

    def test_elephant_isolation_cuts_mice_p99(self):
        sc = get_fabric_scenario("elephant_mice")
        on = FabricSim(sc.build_config(isolate=True), scenario=sc).run()
        off = FabricSim(sc.build_config(isolate=False), scenario=sc).run()
        assert on.violations == [] and off.violations == []
        assert on.elephants_detected == 1 and off.elephants_detected == 1
        assert on.mice_p99_s < off.mice_p99_s
        assert on.mice_completed > 0 and on.elephant_completed > 0

    def test_lb_node_failure_is_hitless(self):
        sc = get_fabric_scenario("lb_node_failure")
        r = FabricSim(sc.build_config(), scenario=sc).run()
        assert r.violations == []
        assert r.lbs_killed and r.bundles_lost == 0
        assert r.bundles_completed == r.bundles_sent

    def test_lb_node_failure_respray_digest_identical(self):
        sc = get_fabric_scenario("lb_node_failure")
        a = FabricSim(sc.build_config(), scenario=sc).run()
        b = FabricSim(sc.build_config(), scenario=sc).run()
        assert _report_key(a) == _report_key(b)


class TestFabricSim:
    def test_event_affinity_across_daqs(self):
        # every (instance, event) pair lands on exactly one member even
        # though 4 DAQs emit bundles for the same events
        sim = FabricSim(_lossless_cfg())
        sim.run()
        assert sim.event_members
        assert all(len(ms) == 1 for ms in sim.event_members.values())

    def test_kill_last_lb_refused(self):
        sim = FabricSim(_lossless_cfg(k_lbs=1, mode="direct"))
        with pytest.raises(ValueError, match="last live"):
            sim.kill_lb(0)

    def test_lane_partition_respected(self):
        # isolation ON: elephants only ever land on reserved members
        sc = get_fabric_scenario("elephant_mice")
        sim = FabricSim(sc.build_config(isolate=True), scenario=sc)
        r = sim.run()
        assert r.violations == [] and r.elephants_detected == 1
        reserved = set(sim.reserved_members)
        for (iid, _ev), members in sim.event_members.items():
            if iid % 2 == 1:                 # reserved-class calendar
                assert members <= reserved

    def test_config_validation(self):
        with pytest.raises(ValueError, match="reserved_fraction"):
            FabricSim(FabricConfig(reserved_fraction=1.5))
        with pytest.raises(ValueError, match="at least one LB"):
            FabricSim(FabricConfig(k_lbs=0))
        with pytest.raises(ValueError, match="one multiplier per DAQ"):
            FabricSim(dataclasses.replace(_lossless_cfg(),
                                          daq_scale=np.ones(3)))


class TestControldFabric:
    def test_lifecycle_and_failure_drain(self):
        cfg = _lossless_cfg(controld=True, steps=10)
        sim = FabricSim(cfg)
        assert sim.fabric_id == "f000000"
        assert len(sim.daemon.sessions) == 2 * cfg.k_lbs
        half = cfg.steps // 2

        for i in range(half):
            sim.step(i)
        victim = sim.live[0]
        sim.kill_lb(victim)
        for tok in sim.tokens[victim]:
            assert tok not in sim.daemon.sessions   # freed via the protocol
        for i in range(half, cfg.steps):
            sim.step(i)

        st = sim.client.status()
        assert len(st["sessions"]) == 2 * (cfg.k_lbs - 1)
        assert len(st["fabrics"][sim.fabric_id]["tokens"]) == \
            2 * (cfg.k_lbs - 1)

    def test_controld_matches_local_calendars(self):
        # the daemon-backed fabric routes bit-identically to local ones
        sc = get_fabric_scenario("elephant_mice")
        local = FabricSim(sc.build_config(), scenario=sc).run()
        daemon = FabricSim(sc.build_config(controld=True), scenario=sc).run()
        assert daemon.violations == []
        assert daemon.mice_p99_s == local.mice_p99_s
        assert daemon.lb_load_bytes == local.lb_load_bytes
