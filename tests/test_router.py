"""Data-plane routing: device router vs host LPM reference (stateless
single-packet property, paper §I-B.3), RSS lanes, dispatch accounting,
virtual-instance isolation."""
import numpy as np
from repro.testing.hypo import given, settings, st

from repro.core import (EpochManager, MemberSpec, dispatch, member_positions,
                        route, split64)
from repro.core.instance import VirtualLoadBalancer
from repro.core.protocol import encode_headers
import jax.numpy as jnp


def _em(weights):
    em = EpochManager(max_members=64)
    members = {i: MemberSpec(node_id=i, base_lane=10 * i, lane_bits=2)
               for i in weights}
    em.initialize(members, weights)
    return em


class TestRoute:
    def test_stateless_single_packet(self):
        """Routing a packet alone == routing it within any batch."""
        em = _em({i: 1.0 for i in range(5)})
        t = em.device_tables()
        evs = np.arange(997, dtype=np.uint64)
        hi, lo = split64(evs)
        ent = (evs % 17).astype(np.uint32)
        batch = route(t, hi, lo, ent)
        for idx in [0, 13, 996]:
            single = route(t, hi[idx:idx+1], lo[idx:idx+1], ent[idx:idx+1])
            assert int(single.member[0]) == int(batch.member[idx])
            assert int(single.lane[0]) == int(batch.lane[idx])

    def test_vs_host_lpm_reference(self):
        em = _em({i: 1.0 for i in range(4)})
        em.reconfigure({i: MemberSpec(node_id=i, base_lane=10 * i, lane_bits=2)
                        for i in range(2, 6)},
                       {i: 1.0 for i in range(2, 6)}, boundary_event=700)
        t = em.device_tables()
        evs = np.arange(1500, dtype=np.uint64)
        hi, lo = split64(evs)
        r = route(t, hi, lo, np.zeros(1500, np.uint32))
        for ev in [0, 5, 699, 700, 701, 1499]:
            eid = em.state.epoch_lpm.lookup(ev)
            cal = em.state.calendars[eid]
            assert int(r.member[ev]) == int(cal[ev & 0x1FF])

    def test_rss_lane_range(self):
        """Entropy maps to base_lane + entropy & (2^bits - 1) (paper §II-B)."""
        em = _em({0: 1.0})
        t = em.device_tables()
        evs = np.zeros(64, np.uint64)
        hi, lo = split64(evs)
        ent = np.arange(64, dtype=np.uint32)
        r = route(t, hi, lo, ent)
        lanes = np.asarray(r.lane)
        assert set(lanes) == {0, 1, 2, 3}  # base 0, 2 bits
        assert (lanes == ent % 4).all()

    def test_header_validation_in_route(self):
        em = _em({0: 1.0, 1: 1.0})
        t = em.device_tables()
        w = encode_headers(np.arange(8, dtype=np.uint64), np.zeros(8, np.uint32))
        w[3, 0] ^= 0x1_0000  # corrupt magic
        hi, lo = w[:, 2], w[:, 3]
        r = route(t, jnp.asarray(hi), jnp.asarray(lo),
                  jnp.zeros(8, jnp.uint32), header_words=jnp.asarray(w))
        v = np.asarray(r.valid)
        assert not v[3] and v.sum() == 7
        assert int(r.member[3]) == -1

    @given(ev=st.integers(0, 2**63), boundary=st.integers(1, 2**62))
    @settings(max_examples=30)
    def test_epoch_lookup_u64_pairs(self, ev, boundary):
        """64-bit boundary comparison via (hi, lo) u32 pairs is exact."""
        em = _em({0: 1.0, 1: 1.0})
        em.reconfigure({2: MemberSpec(node_id=2), 3: MemberSpec(node_id=3)},
                       {2: 1.0, 3: 1.0}, boundary_event=boundary)
        hi, lo = split64(np.asarray([ev], np.uint64))
        r = route(em.device_tables(), hi, lo, np.zeros(1, np.uint32))
        if ev < boundary:
            assert int(r.member[0]) in (0, 1)
        else:
            assert int(r.member[0]) in (2, 3)


class TestDispatch:
    def test_positions_are_stable_and_dense(self):
        member = jnp.asarray([0, 1, 0, 2, 0, 1, -1, 0])
        pos, keep, counts = member_positions(member, 3, capacity=16)
        assert list(np.asarray(pos)[[0, 2, 4, 7]]) == [0, 1, 2, 3]
        assert list(np.asarray(counts)) == [4, 2, 1]
        assert not bool(keep[6])

    def test_every_packet_lands_or_is_counted(self):
        rng = np.random.default_rng(0)
        member = jnp.asarray(rng.integers(0, 5, 300))
        payload = jnp.asarray(rng.normal(size=(300, 8)).astype(np.float32))
        buf, occ, counts = dispatch(payload, member, 5, capacity=40)
        landed = int(occ.sum())
        dropped = int(np.maximum(np.asarray(counts) - 40, 0).sum())
        assert landed + dropped == 300
        # payload integrity: every occupied slot holds a real row
        bufs = np.asarray(buf)[np.asarray(occ) > 0]
        src = set(map(tuple, np.asarray(payload)))
        assert all(tuple(row) in src for row in bufs)


class TestVirtualInstances:
    def test_isolation(self):
        """Paper §I-C: four independent contexts, no leakage. Routed through
        the DataPlane facade (the fused single-pass multi-instance gather)."""
        from repro.core import DataPlane

        vlb = VirtualLoadBalancer()
        vlb.instances[0].initialize({0: MemberSpec(node_id=100)}, {0: 1.0})
        vlb.instances[1].initialize({0: MemberSpec(node_id=200)}, {0: 1.0})
        vlb.instances[2].initialize({0: MemberSpec(node_id=300)}, {0: 1.0})
        vlb.instances[3].initialize({0: MemberSpec(node_id=400)}, {0: 1.0})
        evs = np.arange(16, dtype=np.uint64)
        iid = np.arange(16) % 4
        for backend in ("jnp", "pallas"):
            dp = DataPlane(vlb.device_tables(), backend=backend, interpret=True)
            r = dp.route_events(evs, np.zeros(16, np.uint32), iid)
            nodes = np.asarray(r.node)
            assert (nodes == (np.arange(16) % 4 + 1) * 100).all(), backend

    def test_l2l3_filter_classification(self):
        vlb = VirtualLoadBalancer()
        from repro.core.tables import L2Entry
        vlb.filter.add_l2(L2Entry(mac_da="aa:bb:cc:dd:ee:ff", src_mac="aa:bb:cc:dd:ee:ff"))
        vlb.bind_address(0x0800, "10.0.0.1", "10.0.0.1", instance_id=2)
        assert vlb.classify("aa:bb:cc:dd:ee:ff", 0x0800, "10.0.0.1") == 2
        # reject-by-default at both layers
        assert vlb.classify("11:22:33:44:55:66", 0x0800, "10.0.0.1") is None
        assert vlb.classify("aa:bb:cc:dd:ee:ff", 0x0800, "10.9.9.9") is None
