"""repro.simnet: link/queue primitives vs per-packet references, the
WANTransport degenerate-adapter equivalence, queue-engine parity, telemetry
on the virtual clock (staleness), and end-to-end scenario runs with the
invariant audit (DESIGN.md §SimNet)."""
import dataclasses

import numpy as np
import pytest

from repro.testing.hypo import given, settings, st

from repro.data.transport import TransportConfig, WANTransport
from repro.simnet import (
    FarmConfig,
    FarmQueues,
    Link,
    LinkConfig,
    SCENARIOS,
    Simulator,
    VirtualClock,
    get_scenario,
)
from repro.simnet.links import (
    LinkSet,
    fifo_departures,
    fifo_departures_multi,
    gilbert_elliott_states,
)
from repro.telemetry.metrics import TelemetryHub


def _fifo_ref(t_ready, tx_s, busy_until=-np.inf):
    """Per-packet scalar recurrence: dep_i = max(t_i, dep_{i-1}) + s_i."""
    dep = []
    prev = busy_until
    for t, s in zip(t_ready, tx_s):
        prev = max(t, prev) + s
        dep.append(prev)
    return np.asarray(dep)


class TestVirtualClock:
    def test_monotonic(self):
        c = VirtualClock()
        assert c.now() == 0.0
        c.advance(1.5)
        c.advance_to(1.0)  # no-op backwards
        assert c.now() == 1.5
        with pytest.raises(ValueError):
            c.advance(-1.0)


class TestFifoSerialization:
    @given(seed=st.integers(0, 500))
    @settings(max_examples=20)
    def test_matches_scalar_recurrence(self, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 200))
        t = np.sort(rng.uniform(0, 1.0, n))
        s = rng.uniform(0, 0.01, n)
        busy = float(rng.uniform(-0.5, 0.5))
        dep, last = fifo_departures(t, s, busy)
        np.testing.assert_allclose(dep, _fifo_ref(t, s, busy), rtol=1e-12)
        assert last == dep[-1]

    def test_zero_rate_is_identity(self):
        t = np.asarray([0.0, 1.0, 2.5])
        dep, _ = fifo_departures(t, np.zeros(3))
        np.testing.assert_array_equal(dep, t)

    @given(seed=st.integers(0, 500))
    @settings(max_examples=20)
    def test_multi_matches_per_link_loop(self, seed):
        rng = np.random.default_rng(seed)
        n, n_links = int(rng.integers(1, 300)), int(rng.integers(1, 6))
        link = rng.integers(0, n_links, n)
        t = rng.uniform(0, 1.0, n)
        s = rng.uniform(0, 0.01, n)
        busy = rng.uniform(-0.2, 0.2, n_links)
        got = fifo_departures_multi(link, t, s, busy.copy())
        want = np.empty(n)
        for lk in range(n_links):
            rows = np.flatnonzero(link == lk)
            rows = rows[np.argsort(t[rows], kind="stable")]
            want[rows] = _fifo_ref(t[rows], s[rows], busy[lk])
        np.testing.assert_allclose(got, want, rtol=1e-9)


class TestGilbertElliott:
    def test_deterministic_and_carries_state(self):
        a, sa = gilbert_elliott_states(3, 0, 500, p_gb=0.05, p_bg=0.2,
                                       start_bad=False)
        b, sb = gilbert_elliott_states(3, 0, 500, p_gb=0.05, p_bg=0.2,
                                       start_bad=False)
        np.testing.assert_array_equal(a, b)
        assert sa == sb == bool(a[-1])

    def test_absorbing_good(self):
        s, end = gilbert_elliott_states(0, 0, 200, p_gb=0.0, p_bg=0.5,
                                        start_bad=False)
        assert not s.any() and end is False

    def test_bursty(self):
        s, _ = gilbert_elliott_states(1, 0, 5000, p_gb=0.05, p_bg=0.2,
                                      start_bad=False)
        assert 0 < s.sum() < len(s)
        # sojourns are runs, not iid flips: mean bad-run length ~ 1/p_bg
        flips = np.count_nonzero(s[1:] != s[:-1])
        assert flips < 0.3 * len(s)


class TestDegenerateAdapter:
    @given(seed=st.integers(0, 200))
    @settings(max_examples=15)
    def test_zero_rate_link_equals_wan_transport(self, seed):
        """WANTransport's positional model == a Link with no serialization,
        no propagation, unit-spaced emissions (DESIGN.md §SimNet)."""
        n = 120
        wan = WANTransport(TransportConfig(
            reorder_window=48, loss_prob=0.08, duplicate_prob=0.1, seed=seed))
        link = Link(LinkConfig(jitter_s=48.0, loss_prob=0.08,
                               duplicate_prob=0.1, seed=seed))
        for _ in range(3):  # window counters stay in lockstep
            src, is_dup = wan._plan(n)
            d = link.transit(np.arange(n, dtype=np.float64),
                             np.zeros((n,)))
            np.testing.assert_array_equal(src, d.src)
            np.testing.assert_array_equal(is_dup, d.is_dup)
        assert wan.n_lost == link.n_lost and wan.n_dup == link.n_dup


class TestFarmQueues:
    def _farm(self, cap=10.0, backend="np"):
        return FarmQueues(FarmConfig(
            n_members=1, per_packet_s=np.asarray([1.0]),
            per_byte_s=np.asarray([0.0]), capacity_s=np.asarray([cap])),
            backend=backend)

    def test_lindley_recurrence(self):
        f = self._farm()
        r = f.serve(np.zeros(3, np.int64), np.asarray([0.0, 0.5, 5.0]),
                    np.zeros(3))
        np.testing.assert_allclose(r.depart, [1.0, 2.0, 6.0])
        assert not r.dropped.any()
        assert f.w[0] == 1.0 and f.t_last[0] == 5.0

    def test_drop_tail(self):
        f = self._farm(cap=2.5)
        r = f.serve(np.zeros(3, np.int64), np.asarray([0.0, 0.1, 0.2]),
                    np.zeros(3))
        assert r.dropped.tolist() == [False, False, True]
        assert np.isinf(r.depart[2])
        assert f.n_dropped == 1 and f.n_served == 2

    def test_backlog_decays_across_windows(self):
        f = self._farm()
        f.serve(np.zeros(2, np.int64), np.asarray([0.0, 0.0]), np.zeros(2))
        assert f.w[0] == 2.0
        assert f.fill(now=1.5)[0] == pytest.approx(0.05)  # 0.5s left / 10
        r = f.serve(np.zeros(1, np.int64), np.asarray([10.0]), np.zeros(1))
        np.testing.assert_allclose(r.depart, [11.0])

    @given(seed=st.integers(0, 500))
    @settings(max_examples=10)
    def test_np_jnp_engines_agree(self, seed):
        rng = np.random.default_rng(seed)
        n, m = int(rng.integers(1, 400)), int(rng.integers(1, 8))
        member = rng.integers(0, m, n).astype(np.int64)
        t = rng.uniform(0, 1.0, n)
        nbytes = rng.uniform(0, 4096, n)
        cfg = FarmConfig.uniform(m, per_packet_s=1e-3, per_byte_s=1e-6,
                                 capacity_s=0.05)
        a = FarmQueues(cfg, backend="np").serve(member, t, nbytes)
        b = FarmQueues(cfg, backend="jnp").serve(member, t, nbytes)
        # the jnp engine runs in float32 unless jax_enable_x64 is on
        np.testing.assert_allclose(a.depart, b.depart, rtol=3e-5)
        np.testing.assert_array_equal(a.dropped, b.dropped)
        np.testing.assert_allclose(a.w_end, b.w_end, rtol=3e-5, atol=1e-8)


class TestTelemetryClock:
    def test_injected_clock_stamps_reports(self):
        clock = VirtualClock()
        hub = TelemetryHub(clock=clock.now)
        clock.advance(7.0)
        hub.report_step(0, step_time=0.1)
        assert hub.members[0].last_seen == 7.0

    def test_stale_member_reported_unhealthy(self):
        clock = VirtualClock()
        hub = TelemetryHub(clock=clock.now, stale_after=5.0)
        hub.report_step(0, step_time=0.1)
        hub.report_step(1, step_time=0.1)
        clock.advance(10.0)
        hub.report_queue(1, backlog=0)
        snap = hub.snapshot()
        assert not snap[0].healthy and snap[0].rate == 0.0
        assert snap[1].healthy
        # silence is not a permanent verdict: a fresh report recovers it
        hub.report_step(0, step_time=0.1)
        assert hub.snapshot()[0].healthy

    def test_occupancy_fill_mode_ignores_slowness(self):
        hub = TelemetryHub(queue_capacity=10, fill_mode="occupancy")
        hub.report_step(0, step_time=0.4, backlog=0)   # slow, empty queue
        hub.report_step(1, step_time=0.1, backlog=5)   # fast, half full
        snap = hub.snapshot()
        assert snap[0].fill == 0.0
        assert snap[1].fill == pytest.approx(0.5)


class TestSimulator:
    def test_baseline_run_clean(self):
        sc = get_scenario("baseline")
        r = Simulator(sc.build_config(steps=30), sc).run()
        assert r.violations == []
        assert r.bundles_completed == r.bundles_sent
        assert r.latency_p99_s > r.latency_p50_s > 0
        assert r.sim_time_s > 0

    def test_deterministic(self):
        sc = get_scenario("baseline")
        a = Simulator(sc.build_config(steps=12), sc).run()
        b = Simulator(sc.build_config(steps=12), sc).run()
        assert a.latency_p99_s == b.latency_p99_s
        assert a.latency_p50_s == b.latency_p50_s
        assert a.per_member_segments == b.per_member_segments

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_scenario_matrix_smoke(self, name):
        sc = get_scenario(name)
        r = Simulator(sc.build_config(steps=12), sc).run()
        assert r.violations == [], (name, r.violations)
        assert r.bundles_completed > 0
        assert r.latency_p99_s >= r.latency_p50_s > 0

    def test_multi_instance_partitions_farm(self):
        sc = get_scenario("multi_instance")
        sim = Simulator(sc.build_config(steps=15), sc)
        r = sim.run()
        assert r.violations == []
        # instance 0 members serve only instance-0 events and vice versa
        for (iid, _ev), members in sim.event_members.items():
            for m in members:
                assert m in sim.instance_members[iid]

    def test_straggler_cp_beats_frozen_p99(self):
        sc = get_scenario("straggler")
        closed = Simulator(sc.build_config(steps=90), sc).run()
        frozen = Simulator(sc.build_config(steps=90, frozen_weights=True),
                           dataclasses.replace(sc)).run()
        assert closed.violations == [] and frozen.violations == []
        assert closed.latency_p99_s < frozen.latency_p99_s
        # the straggler's share was actually shed
        w = {int(k): v for k, v in closed.final_weights.items()}
        assert w[0] < 0.75

    def test_wan_duplication_absorbed_and_clean(self):
        """Duplicates on the WAN hop: absorbed by reassembly, never corrupt,
        and the latency pipeline (first-served-copy completion times) stays
        consistent."""
        sc = get_scenario("baseline")
        cfg = sc.build_config(steps=20)
        cfg.wan = dataclasses.replace(cfg.wan, duplicate_prob=0.15,
                                      jitter_s=2e-3)
        r = Simulator(cfg, dataclasses.replace(sc)).run()
        assert r.duplicates_absorbed > 0
        assert r.violations == []
        assert r.latency_p99_s > r.latency_p50_s > 0

    def test_lossy_scenarios_account_everything(self):
        sc = get_scenario("correlated_loss")
        sim = Simulator(sc.build_config(steps=25), sc)
        r = sim.run()
        assert r.packets_lost_wan > 0
        # every bundle is completed, pending, timed out, or had all its
        # segments lost before the reassembler saw any (vanished)
        assert (r.bundles_completed + r.bundles_pending + r.bundles_timed_out
                <= r.bundles_sent)
        assert r.violations == []


class TestLinkSetLoss:
    def test_per_link_loss_vector(self):
        cfgs = [LinkConfig(loss_prob=0.0, seed=4),
                LinkConfig(loss_prob=1.0, seed=4)]
        ls = LinkSet(cfgs)
        link = np.asarray([0, 1, 0, 1], np.int64)
        t, keep = ls.transit(link, np.zeros(4), np.zeros(4))
        assert keep.tolist() == [True, False, True, False]
        assert ls.n_lost == 2


class TestControldScenarios:
    """The control plane as a session service inside the simulator
    (DESIGN.md §Controld): lease churn, hit-less daemon restart, tenancy."""

    def test_lease_churn_drains_hitlessly_with_bundles_accounted(self):
        sc = get_scenario("lease_churn")
        sim = Simulator(sc.build_config(steps=60), sc)
        r = sim.run()
        assert r.violations == [], r.violations
        assert r.leases_expired >= 1
        # the silent member's lease lapsed -> it drained out of the calendar
        # ... and after re-registering it carries traffic again (its segment
        # count keeps growing after the rejoin step)
        assert 1 in sim.daemon.sessions[sim.tokens[0]].cp.members
        # full accounting despite the churn: nothing lost to the drain
        assert (r.bundles_completed + r.bundles_pending + r.bundles_timed_out
                + r.bundles_vanished) == r.bundles_sent

    def test_cp_restart_replays_to_identical_state_mid_run(self):
        sc = get_scenario("cp_restart")
        sim = Simulator(sc.build_config(steps=40), sc)
        r = sim.run()
        assert r.daemon_restarts == 1
        assert r.violations == [], r.violations  # includes the digest audit
        assert r.bundles_completed > 0

    def test_cp_restart_is_invisible_to_the_plant(self):
        """A mid-run daemon restart must not change a single measured
        number: the restarted run equals the unrestarted one exactly."""
        sc = get_scenario("cp_restart")
        with_restart = Simulator(sc.build_config(steps=36), sc).run()
        no_hook = dataclasses.replace(sc, on_step=None)
        without = Simulator(sc.build_config(steps=36), no_hook).run()
        assert with_restart.latency_p99_s == without.latency_p99_s
        assert with_restart.per_member_segments == without.per_member_segments
        assert with_restart.epoch_switches == without.epoch_switches

    def test_multi_tenant_policies_isolated(self):
        sc = get_scenario("multi_tenant")
        sim = Simulator(sc.build_config(steps=30), sc)
        r = sim.run()
        assert r.violations == [], r.violations
        s0 = sim.daemon.sessions[sim.tokens[0]]
        s1 = sim.daemon.sessions[sim.tokens[1]]
        assert s0.policy_name == "proportional"
        assert s1.policy_name == "pid"
        # tenancy: each session only ever saw its own instance's members
        assert set(s0.cp.members) == set(sim.instance_members[0])
        assert set(s1.cp.members) == set(sim.instance_members[1])

    def test_controld_mode_matches_embedded_cp_shape(self):
        """controld-mode baseline stays clean and closes the loop (epoch
        switches happen) — the service is a drop-in for the embedded CP."""
        sc = get_scenario("baseline")
        r = Simulator(sc.build_config(steps=30, controld=True), sc).run()
        assert r.violations == []
        assert r.bundles_completed == r.bundles_sent
        assert r.heartbeats_rejected == 0
